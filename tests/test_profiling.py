"""The paper's profiling pipeline: interview parsing, RAG retrieval,
Eqs (1)-(4), contribution strategies, planner behaviour."""
import numpy as np

from repro.core.profiling import (ContextQuantFeedbackDB, HardwareQuantPerfDB,
                                  InterviewAgent, RAGPlanner, SimLLM,
                                  UnifiedTierPlanner, evaluate_levels,
                                  make_fleet, make_users, plan_round,
                                  satisfaction_score, select_level,
                                  true_performance)
from repro.core.profiling.evaluator import contribution_multiplier
from repro.core.profiling.interview import InferredProfile
from repro.core.profiling.ragdb import embed_features
from repro.core.profiling.users import eq3_score


# ---------------------------------------------------------------------------
# SimLLM parsing (Table I contextual factor inference)
# ---------------------------------------------------------------------------


def test_simllm_parses_location_time_frequency():
    prof = SimLLM().parse(
        "it's in my bedroom. usually at night. a few times a day. "
        "the battery dies fast.")
    assert prof.location == "bedroom"
    assert prof.time == "nighttime"
    assert prof.frequency == "medium"
    assert prof.sens["energy"] > 0


def test_simllm_parses_categories():
    prof = SimLLM().parse("I mostly play music and control the lights")
    assert prof.category_signal.get("entertainment", 0) > 0
    assert prof.category_signal.get("smart_home", 0) > 0


def test_interview_recovers_truth_statistically():
    """Across many users, inferred weight ordering should correlate with
    the ground truth (the parser works through the noise)."""
    users = make_users(60, seed=3)
    agent = InterviewAgent(seed=3)
    hits = total = 0
    for u in users:
        _, prof = agent.interview(u)
        est = prof.weights_estimate()
        true_top = max(u.weights, key=u.weights.get)
        if u.weights[true_top] > 0.45:  # clearly dominant preference
            total += 1
            if max(est, key=est.get) == true_top:
                hits += 1
    assert total > 5
    assert hits / total > 0.55, (hits, total)


# ---------------------------------------------------------------------------
# RAG databases
# ---------------------------------------------------------------------------


def test_embedding_similarity_orders_contexts():
    a = embed_features({"loc_bedroom": 1.0, "time_nighttime": 1.0})
    b = embed_features({"loc_bedroom": 1.0, "time_daytime": 1.0})
    c = embed_features({"loc_kitchen": 1.0, "freq_high": 1.0})
    assert a @ b > a @ c  # shares a factor vs shares none


def test_cqf_db_estimates_from_history():
    db = ContextQuantFeedbackDB()
    ctx_quiet = {"loc_bedroom": 1.0}
    ctx_noisy = {"loc_kitchen": 1.0}
    for _ in range(6):
        db.add_feedback(ctx_quiet, 4, 0.8, {})
        db.add_feedback(ctx_noisy, 4, 0.1, {})
    est_q, conf_q = db.estimate_satisfaction(ctx_quiet, 4)
    est_n, conf_n = db.estimate_satisfaction(ctx_noisy, 4)
    assert est_q > est_n
    assert conf_q > 0.3


def test_hqp_db_retrieves_by_hardware_similarity():
    db = HardwareQuantPerfDB()
    hw_fast = {"class_laptop": 1.0, "cpu_gflops": 1.0}
    hw_slow = {"class_iot_hub": 1.0, "cpu_gflops": 0.01}
    db.add_measurement(hw_fast, 8, {"accuracy": 0.95, "energy": 0.2, "latency": 0.1})
    db.add_measurement(hw_slow, 8, {"accuracy": 0.90, "energy": 0.5, "latency": 0.8})
    est = db.estimate_perf(hw_fast, 8)
    assert est["latency"] < 0.5


# ---------------------------------------------------------------------------
# Eqs (1)-(4)
# ---------------------------------------------------------------------------


def test_eq3_hand_computed():
    w = {"accuracy": 0.5, "energy": 0.3, "latency": 0.2}
    perf = {"accuracy": 0.9, "energy": 0.4, "latency": 0.5}
    c_q = 1.2
    r = c_q * (0.5 * 0.9 + 0.3 * 0.6 + 0.2 * 0.5)          # Eq (1)
    p = 0.5 * 0.1 + 0.3 * 0.4 + 0.2 * 0.5                  # Eq (2)
    assert abs(eq3_score(w, perf, contribution=c_q) - (r - p)) < 1e-9


def test_argmax_selects_best_level():
    fleet = make_fleet(1, seed=0)
    prof = InferredProfile(user_id=0)
    levels = evaluate_levels(prof, fleet[0], ContextQuantFeedbackDB(),
                             HardwareQuantPerfDB())
    best = select_level(levels)                            # Eq (4)
    assert best.score == max(l.score for l in levels)


def test_contribution_strategies_order():
    minority_prof = InferredProfile(user_id=0,
                                    category_signal={"smart_home": 1.0})
    majority_prof = InferredProfile(user_id=1,
                                    category_signal={"entertainment": 1.0})
    ce_min = contribution_multiplier(8, minority_prof, "class_equal")
    ce_maj = contribution_multiplier(8, majority_prof, "class_equal")
    mc_min = contribution_multiplier(8, minority_prof, "majority_centric")
    mc_maj = contribution_multiplier(8, majority_prof, "majority_centric")
    assert ce_min > ce_maj      # class-equal boosts minority-rich clients
    assert mc_maj > mc_min      # majority-centric boosts majority-rich


def test_contribution_increases_with_bits():
    prof = InferredProfile(user_id=0)
    cs = [contribution_multiplier(b, prof, "fedavg") for b in (4, 8, 16, 32)]
    assert cs == sorted(cs)


# ---------------------------------------------------------------------------
# planners (the paper's §IV comparison, small scale)
# ---------------------------------------------------------------------------


def _run(planner, users, fleet, rounds=5):
    sats, energies = [], []
    for r in range(rounds):
        for d, u, s in zip(plan_round(planner.plan(users, fleet)), users, fleet):
            sat = satisfaction_score(u, s, d.bits)
            perf = true_performance(u, s, d.bits)
            planner.observe_feedback(u, s, d.bits, sat, perf)
            if r == rounds - 1:
                sats.append(sat)
                energies.append(perf["energy"])
    return float(np.mean(sats)), float(np.mean(energies))


def test_rag_planner_beats_unified_on_satisfaction_and_energy():
    users = make_users(60, seed=1)
    fleet = make_fleet(60, seed=1)
    u_sat, u_en = _run(UnifiedTierPlanner(), users, fleet)
    r_sat, r_en = _run(RAGPlanner(seed=1), users, fleet)
    assert r_sat > u_sat          # paper: +10% satisfaction
    assert r_en < u_en            # paper: ~20% energy saving


def test_energy_priority_trades_satisfaction_for_energy():
    users = make_users(60, seed=2)
    fleet = make_fleet(60, seed=2)
    r_sat, r_en = _run(RAGPlanner(seed=2), users, fleet)
    e_sat, e_en = _run(RAGPlanner(seed=2, energy_priority=8.0), users, fleet)
    assert e_en < r_en            # more energy saved...
    assert e_sat < r_sat          # ...at a satisfaction cost


def test_decisions_are_hardware_feasible():
    users = make_users(30, seed=4)
    fleet = make_fleet(30, seed=4)
    for d, s in zip(RAGPlanner(seed=4).plan(users, fleet), fleet):
        assert d.bits in s.supported_bits


def test_plan_round_packs_slots():
    users = make_users(40, seed=5)
    fleet = make_fleet(40, seed=5)
    planner = RAGPlanner(seed=5)
    raw = planner.plan(users, fleet)
    packed = plan_round(raw)
    n_levels_raw = len({d.bits for d in raw})
    n_levels_packed = len({d.bits for d in packed})
    assert n_levels_packed <= n_levels_raw
