"""Blockwise uplink scales (DESIGN.md §6): ragged last block, the
n_blocks=1 degenerate case vs the per-row wire format, all-zero blocks,
and mixed bit/block cohorts through the fused aggregation pass."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ota, packing, quant
from repro.kernels import ops, ref
from repro.kernels.ota_fused import sr_dither


def _row(m, seed=0, outlier=True):
    rng = np.random.RandomState(seed)
    row = jnp.asarray(rng.randn(m).astype(np.float32) * 0.01)
    if outlier:
        row = row.at[m // 3].set(40.0)  # one heavy leaf-ish outlier
    return row


def _expand_scales(scale, block, m):
    """Per-block scales -> per-position scales (ragged tail trimmed)."""
    return jnp.repeat(jnp.atleast_1d(scale), block)[:m]


def _reference_symbols(row, bits, sr_seed, row_index, scale_cols):
    """Hand-rolled stochastic quantization given per-position scales.

    Uses the scales the implementation returned: exact scale recompute
    across separate XLA compilations differs in the last ulp (constant
    division folding), so — as everywhere else in this suite — the
    bit-equality contract is over shared scale tensors, not recomputed
    ones.
    """
    qmax = float(quant.qrange(bits))
    pos = jnp.arange(row.shape[0], dtype=jnp.uint32)
    u = sr_dither(jnp.uint32(sr_seed), jnp.uint32(row_index), pos)
    scaled = row / scale_cols
    floor = jnp.floor(scaled)
    q = floor + (u < (scaled - floor)).astype(jnp.float32)
    return jnp.clip(q, -qmax, qmax)


# ---------------------------------------------------------------------------
# quantize_row_sr blockwise semantics
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,block", [(2048, 256), (2048, 768), (4096, 384)])
def test_blockwise_matches_reference_incl_ragged(m, block):
    """Blockwise symbols and scales match the spec, including block sizes
    that do not divide M (ragged last block)."""
    row = _row(m)
    q, scale = quant.quantize_row_sr(row, 4, jnp.uint32(5), 2, block=block)
    n_blocks = -(-m // block)
    assert scale.shape == (n_blocks,)
    padded = jnp.pad(row, (0, n_blocks * block - m))
    amax = jnp.max(jnp.abs(padded.reshape(n_blocks, block)), axis=1)
    np.testing.assert_allclose(
        np.asarray(scale),
        np.asarray(jnp.maximum(amax, 1e-12) / quant.qrange(4)),
        rtol=1e-6,
    )
    q_ref = _reference_symbols(row, 4, 5, 2, _expand_scales(scale, block, m))
    np.testing.assert_array_equal(np.asarray(q.astype(jnp.float32)), np.asarray(q_ref))


def test_ragged_last_block_dequantizes_with_its_own_scale():
    """Symbols past the last full block use the ragged block's scale."""
    m, block = 2048, 768  # 3 blocks: 768 + 768 + 512 (ragged)
    row = _row(m, seed=3)
    r = ota.quantize_uplink(row, 8, jnp.uint32(9), 0, block=block)
    assert r.n_scales == 3 and r.qblock == block
    scale_cols = _expand_scales(r.scale, block, m)
    dq = ota.dequantize_uplink(r)
    want = np.asarray(r.data).astype(np.float32) * np.asarray(scale_cols)
    np.testing.assert_array_equal(np.asarray(dq), want)


def test_blockwise_cuts_outlier_mse():
    """The motivating property: one outlier no longer wrecks the whole
    row's int4 grid."""
    row = _row(4096, seed=7)
    sr = jnp.uint32(11)
    per = ota.quantize_uplink(row, 4, sr, 0)
    blk = ota.quantize_uplink(row, 4, sr, 0, block=256)
    e_per = float(jnp.mean((ota.dequantize_uplink(per) - row) ** 2))
    e_blk = float(jnp.mean((ota.dequantize_uplink(blk) - row) ** 2))
    assert e_blk < e_per


# ---------------------------------------------------------------------------
# n_blocks == 1 degenerate case == the PR-2 per-row wire format
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("block", [0, 2048, 4096])
def test_nblocks1_reproduces_per_row_bitwise(block):
    """block = 0 and block >= M both collapse to the per-row format:
    identical symbols, () scalar scale, qblock 0 — old rows still parse."""
    row = _row(2048, seed=1)
    base = ota.quantize_uplink(row, 4, jnp.uint32(3), 1)
    r = ota.quantize_uplink(row, 4, jnp.uint32(3), 1, block=block)
    assert r.qblock == 0 and jnp.asarray(r.scale).shape == ()
    assert float(r.scale) == float(base.scale)
    np.testing.assert_array_equal(np.asarray(r.data), np.asarray(base.data))


def test_nblocks1_aggregate_equals_pr2_path_exactly():
    """A block >= M cohort aggregates bit-identically to the per-row path
    (and the (K, 1) kernel branch is the PR-2 code path)."""
    m = 2048
    tree = {"w": _row(m, seed=2)}
    lay = packing.make_layout(tree)
    flat = packing.pack(tree, lay)
    key = jax.random.key(17)
    sr = ota.derive_sr_seed(key)
    bits = [4, 8, 4]
    weights = [1.0, 2.0, 0.5]
    rows_a = [ota.quantize_uplink(flat, b, sr, i) for i, b in enumerate(bits)]
    rows_b = [
        ota.quantize_uplink(flat, b, sr, i, block=lay.padded_size)
        for i, b in enumerate(bits)
    ]
    agg_a, _ = ota.ota_aggregate_packed(key, rows_a, bits, weights, lay)
    agg_b, _ = ota.ota_aggregate_packed(key, rows_b, bits, weights, lay)
    for x, y in zip(jax.tree.leaves(agg_a), jax.tree.leaves(agg_b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# all-zero blocks
# ---------------------------------------------------------------------------


def test_all_zero_blocks_stay_exact_zero():
    """A block of exact zeros quantizes to integer 0 and dequantizes to
    exact 0.0 (its amax-floor scale never divides by zero) — the property
    the padded-norm AWGN calibration relies on."""
    m, block = 1024, 256
    row = jnp.zeros((m,), jnp.float32)
    row = row.at[:block].set(_row(block, seed=4, outlier=False))
    for bits in (4, 8, 16):
        r = ota.quantize_uplink(row, bits, jnp.uint32(21), 0, block=block)
        scales = np.asarray(jnp.atleast_1d(r.scale))
        assert np.isfinite(scales).all() and (scales > 0).all()
        dq = np.asarray(ota.dequantize_uplink(r))
        assert (dq[block:] == 0.0).all()
        assert np.abs(dq[:block]).max() > 0


# ---------------------------------------------------------------------------
# mixed 4/8-bit cohorts with different block sizes in one round
# ---------------------------------------------------------------------------


def _mixed_round(m=2048, seed=5):
    tree = {"w": _row(m, seed=seed)}
    lay = packing.make_layout(tree)
    flat = packing.pack(tree, lay)
    key = jax.random.key(29)
    sr = ota.derive_sr_seed(key)
    bits = [4, 8, 4, 8, 32]
    blocks = [256, 0, 128, 256, 256]
    rows = [
        ota.quantize_uplink(flat, b, sr, i, block=bl)
        for i, (b, bl) in enumerate(zip(bits, blocks))
    ]
    weights = [1.0, 2.0, 0.5, 1.5, 1.0]
    return lay, key, bits, rows, weights


def test_mixed_block_sizes_group_separately():
    """Same storage class at different block sizes cannot share a stacked
    scale matrix — grouping must key on (kind, qblock)."""
    _, _, _, rows, _ = _mixed_round()
    kinds, datas, scales, perm = ota._group_rows(rows)
    assert ("int4", 128) in kinds and ("int4", 256) in kinds
    assert ("int8", 0) in kinds and ("int8", 256) in kinds
    for (kind, qblock), s in zip(kinds, scales):
        assert s.ndim == 2
        if qblock == 0:
            assert s.shape[1] == 1
    assert sorted(np.asarray(perm).tolist()) == list(range(len(rows)))


def test_mixed_block_cohort_kernel_bit_equal_to_oracle():
    """The acceptance contract on the mixed bits x blocks round: the
    interpret-mode Pallas kernel == the jnp oracle, bitwise."""
    lay, key, bits, rows, weights = _mixed_round()
    a_ker, _ = ota.ota_aggregate_packed(key, rows, bits, weights, lay, use_kernel=True)
    a_jnp, info = ota.ota_aggregate_packed(
        key, rows, bits, weights, lay, use_kernel=False
    )
    for x, y in zip(jax.tree.leaves(a_ker), jax.tree.leaves(a_jnp)):
        assert np.isfinite(np.asarray(y)).all()
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert info["uplink_bytes"] == sum(r.wire_nbytes for r in rows)


def test_unaligned_block_size_kernel_bit_equal_to_oracle():
    """Block sizes that do not divide the kernel tile width (768 vs
    BLOCK_COLS = 2048) take the resident-matrix gather path instead of
    the streamed aligned slices — still bit-equal to the oracle."""
    m = 4096
    tree = {"w": _row(m, seed=9)}
    lay = packing.make_layout(tree)
    flat = packing.pack(tree, lay)
    key = jax.random.key(41)
    sr = ota.derive_sr_seed(key)
    bits = [4, 8]
    rows = [ota.quantize_uplink(flat, b, sr, i, block=768) for i, b in enumerate(bits)]
    assert rows[0].qblock == 768
    a_ker, _ = ota.ota_aggregate_packed(
        key, rows, bits, [1.0, 2.0], lay, use_kernel=True
    )
    a_jnp, _ = ota.ota_aggregate_packed(
        key, rows, bits, [1.0, 2.0], lay, use_kernel=False
    )
    for x, y in zip(jax.tree.leaves(a_ker), jax.tree.leaves(a_jnp)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_mixed_block_cohort_matches_manual_superposition():
    """The grouped blockwise pass equals the naive per-row dequant +
    weighted sum + shared AWGN epilogue."""
    lay, key, bits, rows, weights = _mixed_round()
    agg, info = ota.ota_aggregate_packed(key, rows, bits, weights, lay)
    cfg = ota.OTAConfig()
    _, _, w = ota._round_channel(key, jnp.asarray(weights, jnp.float32), cfg=cfg)
    acc = sum(w[i] * ota.dequantize_uplink(r) for i, r in enumerate(rows))
    y, noise_std = ota._awgn_epilogue(key, acc, cfg=cfg, n_valid=lay.size)
    want = packing.unpack(y, lay, cast=False)
    for x, v in zip(jax.tree.leaves(agg), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(v), rtol=1e-5, atol=1e-6)
    assert abs(info["noise_std"] - float(noise_std)) < 1e-8


# ---------------------------------------------------------------------------
# wire-byte accounting
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bits,block", [(4, 256), (8, 256), (8, 768), (16, 0)])
def test_row_wire_bytes_counts_scale_vector(bits, block):
    m = 2048
    row = _row(m, seed=6)
    r = ota.quantize_uplink(row, bits, jnp.uint32(13), 0, block=block)
    assert r.wire_nbytes == packing.row_wire_bytes(bits, m, block=block)
    per_row = packing.row_wire_bytes(bits, m)
    extra = 4 * (packing.n_scale_blocks(block, m) - 1)
    assert r.wire_nbytes == per_row + extra


def test_dequant_superpose_accepts_blockwise_scale_matrix():
    """Direct kernel/oracle call with a (K, n_blocks) scale matrix."""
    rng = np.random.RandomState(8)
    K, m, qblock = 3, 4096, 512
    n_blocks = m // qblock
    w = jnp.asarray(rng.uniform(0, 1, K), jnp.float32)
    scales = jnp.asarray(rng.uniform(0.01, 0.2, (K, n_blocks)), jnp.float32)
    q = jnp.asarray(rng.randint(-127, 128, size=(K, m)), jnp.int8)
    got = ops.ota_dequant_superpose(q, scales, w, qblock=qblock)
    want = ref.ota_packed_ref(q, scales, w, qblock=qblock)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # and the gather agrees with an explicit per-column expansion
    expand = jnp.repeat(scales, qblock, axis=1)
    manual = jnp.sum(q.astype(jnp.float32) * expand * w.reshape(-1, 1), axis=0)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(manual), rtol=1e-6, atol=1e-7
    )
