"""Run multi-device jax snippets in a forced-multi-device subprocess.

The main pytest process keeps the default single CPU device (the rest of
the suite depends on it), and ``XLA_FLAGS=--xla_force_host_platform_
device_count=N`` only takes effect before the first jax import — so
anything needing a real multi-device mesh runs as a child interpreter
with the flag set in its environment. Extracted from
tests/test_distributed.py so every multi-device suite (that module and
tests/test_mesh_dataplane.py) shares one helper: env setup, src/ on
PYTHONPATH, a timeout, and both output streams surfaced on failure.
"""

import os
import subprocess
import sys
import textwrap

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")

# 8 forced host devices: enough for a (2, 4) data/model mesh and every
# power-of-two data-shard count the mesh-dataplane tests sweep
DEVICE_COUNT = 8

# generous: child interpreters pay the full jax import + trace cost
TIMEOUT_S = 560


def run_multidevice(
    script: str, *, devices: int = DEVICE_COUNT, timeout: float = TIMEOUT_S
) -> str:
    """Execute ``script`` (dedented) in a child interpreter with
    ``devices`` forced host devices and ``src/`` on PYTHONPATH.

    Returns the child's stdout; a nonzero exit asserts with both streams
    in the failure message so pytest shows the real traceback.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = REPO_SRC
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(script)],
        capture_output=True,
        text=True,
        env=env,
        timeout=timeout,
    )
    assert out.returncode == 0, (
        f"multidevice child failed (rc={out.returncode})\n"
        f"--- stdout ---\n{out.stdout}\n--- stderr ---\n{out.stderr}"
    )
    return out.stdout
