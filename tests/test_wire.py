"""Packed uplink wire format (DESIGN.md §6): row-major int4 round-trips,
edge quantization, packed-rows aggregation equivalence, and kernel/oracle
bit-equality for the dequant+superpose pass."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ota, packing, quant
from repro.kernels import ops, ref
from repro.kernels.ops import pack_int4_rows, unpack_int4_rows


# ---------------------------------------------------------------------------
# row-major int4 pack/unpack
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m", [1, 2, 7, 8, 63, 64, 4097])
def test_pack_int4_rows_roundtrip_odd_even(m):
    rng = np.random.RandomState(m)
    q = jnp.asarray(rng.randint(-8, 8, size=(m,)), jnp.int8)
    p = pack_int4_rows(q)
    assert p.dtype == jnp.uint8 and p.shape == ((m + 1) // 2,)
    assert jnp.array_equal(unpack_int4_rows(p, m), q)


def test_pack_int4_rows_2d_and_half_bytes():
    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.randint(-8, 8, size=(5, 64)), jnp.int8)
    p = pack_int4_rows(q)
    assert p.nbytes == q.nbytes // 2
    assert jnp.array_equal(unpack_int4_rows(p), q)


def test_pack_int4_rows_is_row_major():
    # adjacent *elements* share a byte (low nibble first) — the wire
    # layout the in-kernel unpack depends on, unlike pack_int4's
    # adjacent-*rows* weight layout
    q = jnp.asarray([1, -2, 3, -4], jnp.int8)
    p = np.asarray(pack_int4_rows(q))
    assert p[0] == (1 | ((-2 & 0xF) << 4))
    assert p[1] == (3 | ((-4 & 0xF) << 4))


# ---------------------------------------------------------------------------
# client-side uplink quantization
# ---------------------------------------------------------------------------


def test_quantize_row_sr_storage_classes():
    row = jnp.asarray(np.random.RandomState(1).randn(256), jnp.float32)
    seed = jnp.uint32(7)
    for bits, dtype in [(4, jnp.int8), (8, jnp.int8), (16, jnp.int16)]:
        q, scale = quant.quantize_row_sr(row, bits, seed, 0)
        assert q.dtype == dtype
        assert int(jnp.max(jnp.abs(q.astype(jnp.int32)))) <= quant.qrange(bits)
        assert float(scale) > 0
    q32, s32 = quant.quantize_row_sr(row, 32, seed, 0)
    assert q32.dtype == jnp.float32 and float(s32) == 1.0
    np.testing.assert_array_equal(np.asarray(q32), np.asarray(row))


def test_quantize_uplink_padding_stays_zero():
    tree = {"w": jnp.asarray(np.random.RandomState(2).randn(100), jnp.float32)}
    lay = packing.make_layout(tree)
    flat = packing.pack(tree, lay)
    for bits in (4, 8, 16):
        r = ota.quantize_uplink(flat, bits, jnp.uint32(3), 1)
        q = unpack_int4_rows(r.data) if r.kind == "int4" else r.data
        assert int(jnp.abs(q[lay.size :].astype(jnp.int32)).max()) == 0


def test_wire_bytes_4bit_cohort_under_one_seventh():
    """Acceptance: a 4-bit cohort's uplink <= 1/7 the f32 bytes."""
    tree = {"w": jnp.asarray(np.random.RandomState(3).randn(5000), jnp.float32)}
    lay = packing.make_layout(tree)
    flat = packing.pack(tree, lay)
    K = 4
    rows = [ota.quantize_uplink(flat, 4, jnp.uint32(9), i) for i in range(K)]
    wire = sum(r.wire_nbytes for r in rows)
    f32 = 4 * lay.padded_size * K
    assert wire <= f32 / 7, (wire, f32)
    assert packing.row_wire_bytes(4, lay.padded_size) == rows[0].wire_nbytes


# ---------------------------------------------------------------------------
# packed-rows aggregation: equivalence + bit-equality
# ---------------------------------------------------------------------------


def _mixed_updates(n, seed=7):
    rng = np.random.RandomState(seed)
    return [
        {
            "w": jnp.asarray(rng.randn(40, 13).astype(np.float32)),
            "b": [
                jnp.asarray(rng.randn(77).astype(np.float32)),
                jnp.asarray(rng.randn(3, 5, 2).astype(np.float32)),
            ],
        }
        for _ in range(n)
    ]


def _rows_of(ups, bits, lay, key):
    sr = ota.derive_sr_seed(key)
    return [
        ota.quantize_uplink(packing.pack(u, lay), b, sr, i)
        for i, (u, b) in enumerate(zip(ups, bits))
    ]


def test_packed_rows_match_pertree_oracle():
    """Edge-quantized packed rows == the per-tree loop == the f32 matrix
    path, for the same round key (shared dither stream)."""
    ups = _mixed_updates(6)
    bits = [4, 8, 16, 32, 8, 4]
    weights = [1.0, 2.0, 0.5, 1.0, 3.0, 1.5]
    lay = packing.make_layout(ups[0])
    for snr in (80.0, 15.0):
        cfg = ota.OTAConfig(snr_db=snr)
        key = jax.random.key(123)
        rows = _rows_of(ups, bits, lay, key)
        packed, info_p = ota.ota_aggregate_packed(key, rows, bits, weights, lay, cfg)
        tree, info_t = ota.ota_aggregate_pertree(key, ups, bits, weights, cfg)
        flat, _ = ota.ota_aggregate(key, ups, bits, weights, cfg)
        assert jax.tree.structure(packed) == jax.tree.structure(tree)
        for a, b in zip(jax.tree.leaves(packed), jax.tree.leaves(tree)):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5
            )
        for a, b in zip(jax.tree.leaves(packed), jax.tree.leaves(flat)):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5
            )
        assert info_p["participation"] == info_t["participation"]
        assert abs(info_p["noise_std"] - info_t["noise_std"]) < 1e-6


def test_packed_rows_via_ota_aggregate_entrypoint():
    ups = _mixed_updates(4, seed=19)
    bits = [8, 8, 4, 16]
    weights = [1.0, 0.5, 2.0, 1.0]
    lay = packing.make_layout(ups[0])
    key = jax.random.key(77)
    rows = _rows_of(ups, bits, lay, key)
    a, _ = ota.ota_aggregate(key, rows, bits, weights, layout=lay)
    b, _ = ota.ota_aggregate_packed(key, rows, bits, weights, lay)
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_packed_kernel_bit_equal_to_oracle_mixed_4_8():
    """interpret-mode dequant+superpose kernel == jnp oracle, bitwise, on
    a mixed 4/8-bit cohort (the acceptance contract)."""
    ups = _mixed_updates(5, seed=11)
    bits = [4, 8, 4, 8, 4]
    weights = [1.0, 2.0, 0.5, 1.0, 1.5]
    lay = packing.make_layout(ups[0])
    key = jax.random.key(9)
    rows = _rows_of(ups, bits, lay, key)
    cfg = ota.OTAConfig(snr_db=30.0)
    a_ker, _ = ota.ota_aggregate_packed(
        key, rows, bits, weights, lay, cfg, use_kernel=True
    )
    a_jnp, _ = ota.ota_aggregate_packed(
        key, rows, bits, weights, lay, cfg, use_kernel=False
    )
    for a, b in zip(jax.tree.leaves(a_ker), jax.tree.leaves(a_jnp)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_dequant_superpose_kernel_matches_ref_direct():
    """ops.ota_dequant_superpose == ref.ota_packed_ref on raw arrays, for
    every storage class incl. the packed-int4 in-kernel unpack."""
    rng = np.random.RandomState(4)
    K, M = 3, 5000
    w = jnp.asarray(rng.uniform(0, 1, K), jnp.float32)
    scale = jnp.asarray(rng.uniform(0.01, 0.2, K), jnp.float32)
    for dtype, hi in [(jnp.int8, 127), (jnp.int16, 32767)]:
        q = jnp.asarray(rng.randint(-hi, hi + 1, size=(K, M)), dtype)
        got = ops.ota_dequant_superpose(q, scale, w)
        want = ref.ota_packed_ref(q, scale, w)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    q4 = jnp.asarray(rng.randint(-8, 8, size=(K, M)), jnp.int8)
    p4 = pack_int4_rows(q4)
    got = ops.ota_dequant_superpose(p4, scale, w, packed4=True)
    want = ref.ota_packed_ref(p4, scale, w, packed4=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # and both dequantize to the unpacked truth
    np.testing.assert_allclose(
        np.asarray(got),
        np.asarray(ref.ota_packed_ref(q4, scale, w)),
        rtol=1e-6,
        atol=1e-6,
    )


def test_degenerate_and_midrange_bits_match_flat_path():
    """bits <= 1 (empty grid) passes through without NaN, and 17..31-bit
    clients quantize on the wire (int32) exactly like the flat path —
    the same-key equivalence contract holds across odd precisions."""
    ups = _mixed_updates(4, seed=23)
    bits = [1, 20, 8, 4]
    weights = [1.0, 2.0, 1.0, 0.5]
    lay = packing.make_layout(ups[0])
    key = jax.random.key(31)
    rows = _rows_of(ups, bits, lay, key)
    assert rows[0].kind == "float32" and rows[1].kind == "int32"
    packed, _ = ota.ota_aggregate_packed(key, rows, bits, weights, lay)
    flat, _ = ota.ota_aggregate(key, ups, bits, weights)
    for a, b in zip(jax.tree.leaves(packed), jax.tree.leaves(flat)):
        assert np.isfinite(np.asarray(a)).all()
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)


def test_fl_round_uplink_is_packed():
    """The FL server's uplink is PackedRows: bytes logged and well under
    the f32 volume for sub-f32 cohorts."""
    from repro.configs.base import FLConfig
    from repro.fl import FLServer

    cfg = FLConfig(
        n_clients=3,
        clients_per_round=2,
        n_rounds=1,
        local_steps=1,
        local_batch=2,
        lr=1e-3,
        planner="unified",
        seed=3,
    )
    srv = FLServer(cfg, shard_size=4)
    srv.run(1)
    f32 = 4 * srv.layout.padded_size * 2
    assert 0 < srv.last_uplink_bytes < f32
