"""Per-kernel allclose tests vs the pure-jnp oracles, with shape sweeps
(hypothesis) — kernels run in interpret mode on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare env: deterministic fallback sampler
    from _hypothesis_fallback import given, settings, st

from repro.core.quant import qrange
from repro.kernels import ops, ref


@settings(deadline=None, max_examples=12)
@given(st.integers(1, 4000), st.sampled_from([4, 8, 16]), st.integers(0, 2**16))
def test_fake_quant_kernel_matches_ref(n, bits, seed):
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(n).astype(np.float32) * rng.uniform(0.1, 10))
    got = ops.fake_quant(x, bits)
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-12) / qrange(bits)
    want = ref.fake_quant_ref(x, scale, bits)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("shape", [(8, 128), (100, 257), (3, 5000), (1, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fake_quant_kernel_shapes_dtypes(shape, dtype):
    x = jnp.asarray(np.random.RandomState(0).randn(*shape), dtype)
    got = ops.fake_quant(x, 8)
    assert got.shape == shape and got.dtype == dtype
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax, 1e-12) / qrange(8)
    want = ref.fake_quant_ref(x.astype(jnp.float32), scale, 8)
    np.testing.assert_allclose(got.astype(jnp.float32), want, rtol=2e-2, atol=2e-2)


def test_fake_quant_kernel_stochastic_unbiased():
    x = jnp.asarray(np.random.RandomState(1).randn(512), jnp.float32)
    outs = jnp.stack(
        [
            ops.fake_quant(x, 4, stochastic=True, key=jax.random.key(i))
            for i in range(48)
        ]
    )
    amax = jnp.max(jnp.abs(x))
    scale = amax / qrange(4)
    err = jnp.abs(jnp.mean(outs, 0) - x)
    # Bernoulli rounding: per-sample var <= scale^2/4 -> 5 sigma over 48 draws
    assert float(jnp.max(err)) < 5 * float(scale) / (2 * np.sqrt(48)) + 1e-6


@settings(deadline=None, max_examples=10)
@given(st.integers(1, 12), st.integers(10, 6000), st.integers(0, 2**16))
def test_ota_kernel_matches_ref(k, m, seed):
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(k, m).astype(np.float32))
    w = jnp.asarray(rng.uniform(0, 1, k).astype(np.float32))
    noise = jnp.asarray(rng.randn(m).astype(np.float32))
    std = jnp.float32(rng.uniform(0, 0.5))
    got = ops.ota_aggregate(x, w, noise, std)
    want = ref.ota_aggregate_ref(x, w, noise, std)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(deadline=None, max_examples=6)
@given(st.integers(1, 10), st.integers(10, 5000), st.integers(0, 2**16))
def test_ota_fused_kernel_matches_ref(k, m, seed):
    """Fused quantize+superpose kernel (interpret) == jnp oracle, incl.
    the in-kernel positional dither and the sum-of-squares output."""
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(k, m).astype(np.float32))
    bits = rng.choice([4, 8, 16, 32], size=k)
    qmax = jnp.asarray(np.where(bits < 32, 2.0 ** (bits - 1) - 1, 0.0), jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=1)
    scale = jnp.where(
        qmax > 0, jnp.maximum(amax, 1e-12) / jnp.maximum(qmax, 1.0), 1.0
    )
    w = jnp.asarray(rng.uniform(0, 1, k).astype(np.float32))
    sr_seed = jnp.uint32(rng.randint(0, 2**31))
    got_acc, got_ss = ops.ota_quantize_superpose(x, scale, qmax, w, sr_seed)
    want_acc, want_ss = ref.ota_fused_ref(x, scale, qmax, w, sr_seed)
    np.testing.assert_allclose(got_acc, want_acc, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(float(got_ss), float(want_ss), rtol=1e-5)


@settings(deadline=None, max_examples=8)
@given(
    st.integers(1, 300), st.integers(1, 300), st.integers(1, 300), st.integers(0, 2**16)
)
def test_qmatmul_matches_ref(m, k, n, seed):
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(m, k).astype(np.float32))
    w = jnp.asarray(rng.randn(k, n).astype(np.float32))
    wq, sc = ops.quantize_weights(w, 8)
    got = ops.qmatmul(x, wq, sc)
    want = ref.qmatmul_ref(x, wq, sc)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize(
    "B,S,H,KV,D",
    [
        (2, 128, 4, 4, 64),  # MHA, tile-aligned
        (1, 256, 4, 2, 32),  # GQA
        (2, 200, 2, 1, 64),  # MQA, non-tile-multiple seq
    ],
)
def test_flash_attention_matches_naive(B, S, H, KV, D):
    import jax.numpy as jnp

    q = jax.random.normal(jax.random.key(0), (B, S, H, D))
    k = jax.random.normal(jax.random.key(1), (B, S, KV, D))
    v = jax.random.normal(jax.random.key(2), (B, S, KV, D))
    got = ops.flash_mha(q, k, v, causal=True)
    G = H // KV
    kr = jnp.repeat(k, G, axis=2)
    vr = jnp.repeat(v, G, axis=2)
    want = ref.flash_attention_ref(
        q.swapaxes(1, 2).reshape(B * H, S, D),
        kr.swapaxes(1, 2).reshape(B * H, S, D),
        vr.swapaxes(1, 2).reshape(B * H, S, D),
    ).reshape(B, H, S, D).swapaxes(1, 2)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_flash_attention_bf16():
    import jax.numpy as jnp

    q = jax.random.normal(jax.random.key(5), (1, 128, 2, 64), jnp.bfloat16)
    k = jax.random.normal(jax.random.key(6), (1, 128, 2, 64), jnp.bfloat16)
    v = jax.random.normal(jax.random.key(7), (1, 128, 2, 64), jnp.bfloat16)
    got = ops.flash_mha(q, k, v)
    want = ref.flash_attention_ref(
        q.swapaxes(1, 2).reshape(2, 128, 64),
        k.swapaxes(1, 2).reshape(2, 128, 64),
        v.swapaxes(1, 2).reshape(2, 128, 64),
    ).reshape(1, 2, 128, 64).swapaxes(1, 2)
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32), rtol=3e-2, atol=3e-2
    )


def test_qmatmul_int8_close_to_fp32():
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(64, 256).astype(np.float32))
    w = jnp.asarray(rng.randn(256, 128).astype(np.float32))
    wq, sc = ops.quantize_weights(w, 8)
    got = ops.qmatmul(x, wq, sc)
    rel = float(jnp.linalg.norm(got - x @ w) / jnp.linalg.norm(x @ w))
    assert rel < 0.01  # int8 per-channel should be <1% off
