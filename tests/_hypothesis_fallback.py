"""Tiny stand-in for ``hypothesis`` on bare environments.

The tier-1 suite must *collect and run* without the hypothesis wheel
(the container only bakes in the jax toolchain). This module implements
just the surface the tests use — ``given``/``settings`` and the
``integers``/``floats``/``sampled_from``/``composite`` strategies — as a
deterministic seeded sampler: each ``@given`` test runs ``max_examples``
times with pseudo-random draws. No shrinking, no database; coverage is
weaker than real hypothesis but the properties still get exercised.

Usage in a test module::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_fallback import given, settings, st
"""
from __future__ import annotations

import random
import zlib


class _Strategy:
    def __init__(self, sample_fn):
        self._sample_fn = sample_fn

    def sample(self, rng: random.Random):
        return self._sample_fn(rng)


class _Strategies:
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def floats(min_value, max_value, **_):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda rng: rng.choice(elements))

    @staticmethod
    def composite(fn):
        """``fn(draw, *args, **kwargs)`` -> strategy factory, like hypothesis."""

        def build(*args, **kwargs):
            def sample(rng):
                return fn(lambda strat: strat.sample(rng), *args, **kwargs)

            return _Strategy(sample)

        return build


st = _Strategies()
strategies = st

_DEFAULT_EXAMPLES = 10


def given(*strats):
    def deco(test):
        # NB: no functools.wraps — pytest would introspect the wrapped
        # signature via __wrapped__ and demand fixtures for the strategy
        # arguments. The runner takes no arguments at all.
        def runner():
            n = getattr(runner, "_max_examples", _DEFAULT_EXAMPLES)
            # deterministic per-test stream (independent of PYTHONHASHSEED)
            rng = random.Random(zlib.adler32(test.__name__.encode()))
            for _ in range(n):
                test(*[s.sample(rng) for s in strats])

        runner.__name__ = test.__name__
        runner.__module__ = test.__module__
        runner.__doc__ = test.__doc__
        runner.hypothesis_fallback = True
        return runner

    return deco


def settings(deadline=None, max_examples=_DEFAULT_EXAMPLES, **_):
    """Applied outside @given; only max_examples is honoured."""

    def deco(fn):
        fn._max_examples = max_examples
        return fn

    return deco
