"""Mesh-sharded data planes == single-host oracles, *bitwise*
(DESIGN.md §15), on a real forced-8-device mesh.

Every equivalence test runs in a forced-multi-device subprocess
(tests/_multidevice.py) and asserts byte identity (``tobytes``), not
allclose: the sharded OTA fold places the symbol axis across shards and
combines by concatenation, and the sharded retrieval top-k re-merges
per-shard lanes under the engine tie contract — both are bit-identical
to their unsharded paths by construction, which is exactly what these
tests pin. Host-side helpers (shard bounds, chunk alignment, the numpy
host-sharded engine) are tested in-process.
"""

import numpy as np

from _multidevice import run_multidevice


def _header(**params) -> str:
    return "".join(f"{k} = {v!r}\n" for k, v in params.items())


# --- OTA: sharded fold vs ota_aggregate_packed -------------------------

_OTA_BODY = """
import numpy as np, jax, jax.numpy as jnp
from repro.core import ota, packing, wire
from repro.launch.mesh import make_data_mesh

assert len(jax.devices()) == 8, jax.devices()
rng = np.random.RandomState(SEED)
tree = {"a": jnp.zeros((3000,), jnp.float32),
        "b": jnp.zeros((17, 5), jnp.float32)}
layout = packing.make_layout(tree)
key = jax.random.key(3)
sr = ota.derive_sr_seed(key)
rows = []
for j, b in enumerate(BITS):
    full = np.zeros(layout.padded_size, np.float32)
    full[: layout.size] = rng.randn(layout.size).astype(np.float32)
    rows.append(wire.encode_row(jnp.asarray(full), b, sr, j, block=BLOCK))
w = (rng.rand(len(rows)) + 0.5).astype(np.float32)
g = None if GAINS is None else jnp.asarray(GAINS, jnp.float32)
cfg = ota.OTAConfig()
ref, _ = ota.ota_aggregate_packed(
    key, rows, [r.bits for r in rows], w, layout, cfg, gains=g,
    use_kernel=USE_KERNEL)
for D in D_LIST:
    sh, info = ota.ota_aggregate_packed(
        key, rows, [r.bits for r in rows], w, layout, cfg, gains=g,
        use_kernel=USE_KERNEL, mesh=make_data_mesh(D))
    for a, b_ in zip(jax.tree.leaves(ref), jax.tree.leaves(sh)):
        assert np.asarray(a).tobytes() == np.asarray(b_).tobytes(), D
print("ok")
"""


def _ota_case(
    *, seed=0, bits, block=64, gains=None, d_list=(2, 4, 8), use_kernel=False
):
    run_multidevice(
        _header(SEED=seed, BITS=list(bits), BLOCK=block,
                GAINS=None if gains is None else list(gains),
                D_LIST=list(d_list), USE_KERNEL=use_kernel)
        + _OTA_BODY
    )


def test_ota_sharded_int8_blockwise_bitwise():
    _ota_case(bits=[8] * 8)


def test_ota_sharded_int4_blockwise_bitwise():
    _ota_case(bits=[4] * 6, seed=1)


def test_ota_sharded_int16_blockwise_bitwise():
    _ota_case(bits=[16] * 5, seed=2)


def test_ota_sharded_f32_passthrough_bitwise():
    _ota_case(bits=[32] * 4, block=0, seed=3)


def test_ota_sharded_mixed_storage_bitwise():
    # all four storage classes in one cohort: four fold groups
    _ota_case(bits=[4, 8, 16, 32, 8, 4, 16, 32], seed=4)


def test_ota_sharded_per_update_scale_bitwise():
    # qblock = 0: one scale per update (the PR-2 wire format)
    _ota_case(bits=[8, 8, 4, 16], block=0, seed=5)


def test_ota_sharded_gains_bitwise():
    # fading-channel gains ride inside the fold; one truncated (0) row
    _ota_case(bits=[8] * 6, gains=[0.9, 0.0, 1.1, 0.7, 1.0, 0.85], seed=6)


def test_ota_sharded_ragged_cohort_bitwise():
    # K = 7 rows on 8 shards, and K = 3 < shard count: K is never
    # divided by the symbol-axis placement, so ragged cohorts are free
    _ota_case(bits=[8] * 7, seed=7)
    _ota_case(bits=[4, 8, 32], seed=8)


def test_ota_one_shard_mesh_byte_identical():
    # D = 1: the mesh path with a single shard == the non-mesh path
    _ota_case(bits=[8, 4, 32, 16], d_list=(1,), seed=9)


def test_ota_sharded_kernel_path_bitwise():
    # interpret-mode Pallas kernel inside shard_map (check_rep=False is
    # load-bearing: jax 0.4.x has no pallas_call replication rule)
    _ota_case(bits=[4, 8, 16, 8], d_list=(4,), seed=10, use_kernel=True)


def test_ota_accumulator_multiwave_staleness_bitwise():
    run_multidevice("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import ota, packing, wire
        from repro.launch.mesh import make_data_mesh

        rng = np.random.RandomState(11)
        tree = {"a": jnp.zeros((2500,), jnp.float32)}
        layout = packing.make_layout(tree)
        key = jax.random.key(5)
        sr = ota.derive_sr_seed(key)
        rows = []
        for j, b in enumerate([8, 8, 4, 4, 16, 32]):
            full = np.zeros(layout.padded_size, np.float32)
            full[: layout.size] = rng.randn(layout.size).astype(np.float32)
            rows.append(wire.encode_row(jnp.asarray(full), b, sr, j, block=64))
        w = (rng.rand(6) + 0.5).astype(np.float32)
        stale = [0.9, 0.8, 0.7]

        def run(mesh):
            acc = ota.OtaAccumulator(layout, ota.OTAConfig(), mesh=mesh)
            acc.fold(rows[:3], w[:3])
            acc.fold(rows[3:], w[3:], staleness=stale)
            y, _ = acc.finalize(key)
            return y

        ref = run(None)
        for D in (2, 8):
            sh = run(make_data_mesh(D))
            for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(sh)):
                assert np.asarray(a).tobytes() == np.asarray(b).tobytes(), D
        print("ok")
    """)


def test_fl_server_mesh_knob_round_bitwise():
    # end to end: FLConfig.mesh_data_shards=4 vs 0 — identical params.
    # TWO rounds on purpose: round 2's uplink rows are built from the
    # gathered (device-0-committed) round-1 broadcast, the placement
    # that once crashed the jitted shard_map (explicit _place fix).
    run_multidevice("""
        import numpy as np, jax
        from repro.configs.base import FLConfig
        from repro.fl import FLServer

        assert len(jax.devices()) == 8

        def run(shards):
            cfg = FLConfig(n_clients=6, clients_per_round=3, n_rounds=2,
                           local_steps=1, local_batch=2, lr=1e-3,
                           planner="unified", seed=0,
                           mesh_data_shards=shards)
            srv = FLServer(cfg, shard_size=6)
            srv.run_round(0)
            srv.run_round(1)
            return srv

        a, b = run(0), run(4)
        assert a.mesh is None and b.mesh is not None
        for x, y in zip(jax.tree.leaves(a.params), jax.tree.leaves(b.params)):
            assert np.asarray(x).tobytes() == np.asarray(y).tobytes()
        print("ok")
    """)


# --- retrieval: sharded arena top-k vs the unsharded engine ------------

_RET_BODY = """
import numpy as np, jax, jax.numpy as jnp
from repro.retrieval.arena import ArenaStore
from repro.retrieval.engine import (
    RetrievalEngine, brute_force_topk, normalize_rows)
from repro.kernels.ops import topk_cosine
from repro.launch.mesh import make_data_mesh

assert len(jax.devices()) == 8, jax.devices()
rng = np.random.RandomState(SEED)
if GRID:
    base = rng.randint(-3, 4, size=(N // 16, 64)).astype(np.float32)
    vecs = np.concatenate([base] * 16)  # heavy score ties, exact dots
    qm = rng.randint(-3, 4, size=(4, 64)).astype(np.float32)
else:
    vecs = normalize_rows(rng.randn(N, 64))
    qm = normalize_rows(rng.randn(5, 64))
store = ArenaStore(64, storage=STORAGE)
store.add_batch(vecs)

# single-host anchor: the unsharded fused-path oracle on the raw slab
data, scales = store.raw()
s0, i0 = topk_cosine(
    jnp.asarray(qm), jnp.asarray(data),
    None if scales is None else jnp.asarray(scales),
    jnp.int32(len(store)), k=K_SEL, use_kernel=False)
s0, i0 = np.asarray(s0), np.asarray(i0)
for D in D_LIST:
    eng = RetrievalEngine(store, use_kernel=False, mesh=make_data_mesh(D))
    s1, i1 = eng.topk(qm, K_SEL)
    assert s0.tobytes() == s1.tobytes(), D
    assert i0.tobytes() == i1.tobytes(), D
if GRID:  # integer grid: every path's dots are exact -> equals the spec
    sb, ib = brute_force_topk(store.vectors(), qm, K_SEL)
    assert sb.tobytes() == s0.tobytes() and ib.tobytes() == i0.tobytes()
print("ok")
"""


def _ret_case(*, seed=0, n, k, storage="f32", grid=False, d_list=(2, 4, 8)):
    run_multidevice(
        _header(SEED=seed, N=n, K_SEL=k, STORAGE=storage, GRID=grid,
                D_LIST=list(d_list))
        + _RET_BODY
    )


def test_retrieval_sharded_f32_ragged_n_bitwise():
    # n = 1000 live rows: not a multiple of the shard size, pad tiles
    # masked to -inf on the last live shard and empty trailing shards
    _ret_case(n=1000, k=16)


def test_retrieval_sharded_tied_scores_exact():
    # duplicated integer-grid rows: ties across shard boundaries must
    # resolve to ascending global index — and match brute force exactly
    _ret_case(n=640, k=20, grid=True, seed=1)


def test_retrieval_sharded_k_larger_than_shard_live():
    # k = 100 exceeds any single shard's live rows (300 over 8 shards)
    _ret_case(n=300, k=100, seed=2)


def test_retrieval_sharded_int8_bitwise():
    _ret_case(n=2000, k=32, storage="int8", seed=3)


def test_retrieval_one_shard_mesh_byte_identical():
    _ret_case(n=512, k=8, d_list=(1,), seed=4)


# --- host-side helpers: no mesh needed, run in-process -----------------


def test_arena_shard_bounds_tile_aligned_cover_capacity():
    from repro.kernels.topk_similarity import TILE_N
    from repro.retrieval.arena import ArenaStore

    store = ArenaStore(64, capacity=1024)
    for n_shards in (1, 2, 4, 8):
        bounds = store.shard_bounds(n_shards)
        assert len(bounds) == n_shards
        assert bounds[0][0] == 0 and bounds[-1][1] == store.capacity
        for (lo, hi), (lo2, _) in zip(bounds, bounds[1:]):
            assert hi == lo2  # contiguous
        for lo, hi in bounds:
            assert lo % TILE_N == 0 and lo <= hi
        rows = store.shard_rows(n_shards)
        assert rows % TILE_N == 0
        assert rows * n_shards >= store.capacity


def test_arena_shard_nbytes_reduction():
    from repro.retrieval.arena import ArenaStore

    for storage in ("f32", "int8"):
        store = ArenaStore(64, storage=storage, capacity=16384)
        full = store.shard_nbytes(1)
        assert full >= store.nbytes or len(store) == 0
        assert full / store.shard_nbytes(4) == 4.0
        assert full / store.shard_nbytes(8) == 8.0


def test_ota_shard_chunk_alignment():
    from repro.core.ota import _shard_chunk

    assert _shard_chunk(4096, 8, (("int8", 64),)) == 512
    # mixed qblocks align to the lcm so every block stays whole
    assert _shard_chunk(4096, 8, (("int8", 64), ("int16", 96))) == 576
    # int4 nibble pairs force even chunks even without blockwise scales
    assert _shard_chunk(101, 8, (("int4", 0),)) % 2 == 0
    for m, d, qb in [(3328, 8, 64), (1000, 4, 128), (17, 8, 0)]:
        kinds = (("int8", qb),)
        mc = _shard_chunk(m, d, kinds)
        assert mc * d >= m
        assert mc % 2 == 0
        if qb:
            assert mc % qb == 0


def test_numpy_sharded_engine_matches_brute_force():
    from repro.retrieval.arena import ArenaStore
    from repro.retrieval.engine import RetrievalEngine, brute_force_topk

    # f32 integer-grid fixture: every GEMM's dots are exact, so the
    # host-sharded per-shard GEMMs equal the single-GEMM brute force
    # bit for bit. (int8 dequantized slabs are NOT integer-grid — the
    # BLAS last-ulp caveat in _topk_numpy_sharded's docstring — so the
    # bitwise int8 coverage lives in the jax mesh lane above.)
    rng = np.random.RandomState(7)
    base = rng.randint(-3, 4, size=(40, 64)).astype(np.float32)
    vecs = np.concatenate([base] * 16)  # exact f32 dots + heavy ties
    qm = rng.randint(-3, 4, size=(4, 64)).astype(np.float32)
    store = ArenaStore(64)
    store.add_batch(vecs)
    sb, ib = brute_force_topk(store.vectors(), qm, 20)
    for n_shards in (2, 3, 8):
        eng = RetrievalEngine(store, n_shards=n_shards)
        s1, i1 = eng.topk(qm, 20)
        np.testing.assert_array_equal(sb, s1)
        np.testing.assert_array_equal(ib, i1)


def test_merge_candidates_tie_contract():
    from repro.retrieval.engine import merge_candidates

    # two chunks, overlapping tied scores: lowest global index wins
    s_a = np.array([[3.0, 1.0]], np.float32)
    i_a = np.array([[0, 5]], np.int32)
    s_b = np.array([[3.0, 2.0]], np.float32)
    i_b = np.array([[7, 9]], np.int32)
    s, i = merge_candidates([s_a, s_b], [i_a, i_b], 3)
    np.testing.assert_array_equal(s, [[3.0, 3.0, 2.0]])
    np.testing.assert_array_equal(i, [[0, 7, 9]])
