"""End-to-end behaviour tests: a whole FL round pipeline (profiling ->
planning -> quantized local training -> OTA aggregation -> feedback), and
the system-level claims at miniature scale."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FLConfig
from repro.fl import FLServer


@pytest.fixture(scope="module")
def mini_server():
    cfg = FLConfig(n_clients=8, clients_per_round=4, n_rounds=2,
                   local_steps=1, local_batch=2, lr=1e-3, planner="rag",
                   seed=0)
    srv = FLServer(cfg, shard_size=6)
    srv.run(2)
    return srv


def test_fl_rounds_complete_and_finite(mini_server):
    logs = mini_server.round_logs
    assert len(logs) == 2
    for log in logs:
        assert np.isfinite(log.train_loss)
        assert log.n_participating >= 1
        assert 0 <= log.mean_energy <= 1


def test_global_params_updated(mini_server):
    fresh = mini_server.model.init(jax.random.key(mini_server.cfg.seed))
    diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))),
        fresh, mini_server.params)
    assert max(jax.tree.leaves(diffs)) > 0


def test_rag_databases_accumulate(mini_server):
    planner = mini_server.planner
    assert len(planner.cqf_db) == 8   # 2 rounds x 4 clients
    assert len(planner.hqp_db) == 8


def test_planned_bits_feasible(mini_server):
    for log in mini_server.round_logs:
        for uid, bits in log.bits.items():
            assert bits in mini_server.fleet[uid].supported_bits


def test_evaluate_reports_all_categories(mini_server):
    acc = mini_server.evaluate()
    assert set(acc) == {"entertainment", "smart_home", "general_query",
                        "personal_request"}
    for v in acc.values():
        assert 0.0 <= v <= 1.0


def test_loss_decreases_over_training():
    """A few more rounds on one client cohort: CTC loss should descend."""
    cfg = FLConfig(n_clients=4, clients_per_round=4, n_rounds=4,
                   local_steps=3, local_batch=4, lr=2e-3, planner="unified",
                   seed=1)
    srv = FLServer(cfg, shard_size=8)
    logs = srv.run(4)
    assert logs[-1].train_loss < logs[0].train_loss
