"""Checkpoint round-trip and rolling-GC behaviour."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import CheckpointManager, load_checkpoint, save_checkpoint


def _tree():
    return {
        "params": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
                   "b": jnp.ones((4,), jnp.bfloat16)},
        "opt": [jnp.zeros((2, 2)), (jnp.asarray(3), jnp.asarray(2.5))],
        "step": jnp.asarray(7, jnp.int32),
    }


def test_roundtrip(tmp_path):
    p = str(tmp_path / "ck.msgpack.zst")
    tree = _tree()
    save_checkpoint(p, tree, meta={"note": "hi"})
    got, meta = load_checkpoint(p)
    assert meta["note"] == "hi"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        assert a.dtype == b.dtype, (a.dtype, b.dtype)
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))


def test_structure_preserved(tmp_path):
    p = str(tmp_path / "ck.msgpack.zst")
    tree = _tree()
    save_checkpoint(p, tree)
    got, _ = load_checkpoint(p)
    assert jax.tree.structure(tree) == jax.tree.structure(got)


def test_manager_rolls(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (10, 20, 30):
        mgr.save(s, {"x": jnp.asarray([s])})
    assert mgr.latest_step() == 30
    got, meta = mgr.restore_latest()
    assert int(got["x"][0]) == 30 and meta["step"] == 30
    assert len(mgr._steps()) == 2  # step 10 garbage-collected
