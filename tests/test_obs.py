"""Telemetry layer (DESIGN.md §14): span tracer semantics (nesting,
disabled no-op, Perfetto export), the metrics registry (labels,
histograms, reset), the jit-retrace counter's regression guard, and the
instrumented round loop's acceptance properties — FLServer and
StreamingFLServer emit the same span names, the metrics byte counters
agree bit-for-bit with the ``RoundLog`` that fed them, and a disabled
tracer leaves round outputs byte-identical."""
import json
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.base import FLConfig
from repro.core import ota, packing
from repro.fl import FLServer, StreamingFLServer


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------


def test_nested_span_order_and_depth():
    with obs.enabled() as t:
        with obs.span("outer", tag=1):
            with obs.span("inner"):
                pass
            with obs.span("inner"):
                pass
    evs = t.events
    # children record on exit, before their parent
    assert [e.name for e in evs] == ["inner", "inner", "outer"]
    outer = evs[-1]
    assert outer.depth == 0 and outer.args == {"tag": 1}
    for inner in evs[:2]:
        assert inner.depth == 1
        # interval containment: the Perfetto nesting invariant
        assert inner.ts_us >= outer.ts_us
        assert inner.ts_us + inner.dur_us <= outer.ts_us + outer.dur_us + 1e-3


def test_disabled_tracer_records_nothing():
    t = obs.get_tracer()
    t.reset()
    assert not obs.is_enabled()
    # the disabled fast path returns the shared no-op singleton:
    # no allocation, no clock read, nothing recorded
    s = obs.span("anything", k=1)
    assert s is obs.NULL_SPAN
    with s:
        pass
    assert t.events == [] and t.span_names() == set()


def test_span_dropped_if_disabled_mid_flight():
    t = obs.get_tracer()
    with obs.enabled():
        s = obs.span("doomed")
        with s:
            t.disable()
        t.enable()
    assert "doomed" not in t.span_names()


def test_enabled_restores_prior_state():
    assert not obs.is_enabled()
    with obs.enabled():
        assert obs.is_enabled()
        with obs.disabled():
            assert not obs.is_enabled()
        assert obs.is_enabled()
    assert not obs.is_enabled()


def test_traced_decorator():
    calls = []

    @obs.traced("deco.fn")
    def fn(x):
        calls.append(x)
        return x + 1

    assert fn(1) == 2  # disabled: plain passthrough
    with obs.enabled() as t:
        assert fn(2) == 3
        assert t.summary()["deco.fn"]["count"] == 1
    assert calls == [1, 2]


def test_perfetto_export_roundtrips():
    with obs.enabled() as t:
        with obs.span("a", k=3):
            with obs.span("b"):
                pass
    doc = json.loads(t.export_perfetto())
    evs = doc["traceEvents"]
    assert len(evs) == 2
    for ev in evs:
        for key in ("name", "ph", "ts", "dur", "pid", "tid", "cat"):
            assert key in ev
        assert ev["ph"] == "X"
    # sorted by start time: parent first in the export
    assert [e["name"] for e in evs] == ["a", "b"]
    assert evs[0]["args"] == {"k": 3}


def test_perfetto_export_writes_file(tmp_path):
    with obs.enabled() as t:
        with obs.span("x"):
            pass
    path = tmp_path / "trace.json"
    text = t.export_perfetto(str(path))
    assert json.loads(path.read_text()) == json.loads(text)


def test_spans_keep_their_thread_id():
    with obs.enabled() as t:
        with obs.span("main_thread"):
            pass
        # record a span wholly on the worker thread
        def work():
            with t.span("worker"):
                pass
        th = threading.Thread(target=work)
        th.start()
        th.join()
    tids = {e.name: e.tid for e in t.events}
    assert tids["main_thread"] != tids["worker"]


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_registry_counters_gauges_histograms():
    r = obs.metrics.Registry()
    r.inc("c")
    r.inc("c", 2.5)
    r.set_gauge("g", 7.0)
    r.set_gauge("g", 8.0)  # last write wins
    for v in (1.0, 3.0, 2.0):
        r.observe("h", v)
    snap = r.snapshot()
    assert snap["counters"]["c"] == 3.5
    assert snap["gauges"]["g"] == 8.0
    h = snap["histograms"]["h"]
    assert h == {"count": 3, "total": 6.0, "min": 1.0, "max": 3.0}
    r.reset()
    assert r.snapshot() == {"counters": {}, "gauges": {}, "histograms": {}}


def test_registry_labels_make_distinct_series():
    r = obs.metrics.Registry()
    r.inc("rows", 2, kind="int4")
    r.inc("rows", 3, kind="f32")
    r.inc("rows", 1, kind="int4")
    snap = r.snapshot()["counters"]
    assert snap["rows{kind=int4}"] == 3
    assert snap["rows{kind=f32}"] == 3
    assert r.get("rows", kind="int4") == 3


def test_jsonl_sink_and_dump(tmp_path):
    r = obs.metrics.Registry()
    r.inc("fl.uplink_bytes", 128)
    with obs.enabled() as t:
        with obs.span("round"):
            pass
    jsonl = tmp_path / "events.jsonl"
    trace = tmp_path / "trace.json"
    s = obs.export.dump_telemetry(str(jsonl), str(trace), registry=r,
                                  tracer=t)
    lines = [json.loads(ln) for ln in jsonl.read_text().splitlines()]
    kinds = {(ln["kind"], ln["name"]) for ln in lines}
    assert ("counter", "fl.uplink_bytes") in kinds
    assert ("span", "round") in kinds
    assert s["metrics"]["counters"]["fl.uplink_bytes"] == 128
    assert json.loads(trace.read_text())["traceEvents"]


# ---------------------------------------------------------------------------
# jit-retrace regression guard
# ---------------------------------------------------------------------------


def _packed_round(key, seed):
    """One mixed-bit packed aggregation round (fresh values, same shapes)."""
    rng = np.random.RandomState(seed)
    tree = {"w": jnp.zeros((2048,), jnp.float32)}
    layout = packing.make_layout(tree)
    bits = [4, 8, 16, 32]
    sr = ota.derive_sr_seed(key)
    rows = [
        ota.quantize_uplink(
            jnp.asarray(rng.randn(layout.padded_size).astype(np.float32)),
            b, sr, i)
        for i, b in enumerate(bits)
    ]
    out, _ = ota.ota_aggregate_packed(
        key, rows, bits, [1.0, 2.0, 1.0, 3.0], layout,
        ota.OTAConfig(snr_db=20.0))
    jax.block_until_ready(jax.tree.leaves(out))


def test_jit_retrace_counter_flat_on_second_round():
    """Round 2 of an identical-composition cohort must hit the jit cache:
    the ``jax.retraces`` counter (fed by the jax.monitoring hook) stays
    flat — the regression guard for shape/dtype-unstable round code."""
    _packed_round(jax.random.key(0), seed=0)  # warm every program
    obs.metrics.reset()
    _packed_round(jax.random.key(1), seed=1)
    warm = obs.metrics.get("jax.retraces")
    _packed_round(jax.random.key(2), seed=2)
    assert obs.metrics.get("jax.retraces") == warm, (
        "aggregation retraced on an identical cohort composition")


# ---------------------------------------------------------------------------
# instrumented round loop
# ---------------------------------------------------------------------------


def _cfg(**kw):
    base = dict(n_clients=6, clients_per_round=3, n_rounds=2, local_steps=1,
                local_batch=2, lr=1e-3, planner="unified", seed=0)
    base.update(kw)
    return FLConfig(**base)


def _run_one_round(server_cls, *, enabled):
    ctx = obs.enabled() if enabled else obs.disabled()
    with ctx:
        n0 = len(obs.get_tracer().events)
        obs.metrics.reset()
        srv = server_cls(_cfg(), shard_size=4)
        log = srv.run_round(0)
        names = {e.name for e in obs.get_tracer().events[n0:]}
        snap = obs.metrics.snapshot()
    return srv, log, names, snap


def test_servers_emit_same_span_names():
    """No deadline, full fill: the streaming engine's trace is the
    synchronous engine's trace — identical span name sets (and >= 7
    distinct pipeline stages, the acceptance floor)."""
    _, _, sync_names, _ = _run_one_round(FLServer, enabled=True)
    _, _, stream_names, _ = _run_one_round(StreamingFLServer, enabled=True)
    assert sync_names == stream_names
    assert len(sync_names) >= 7
    assert {"round", "plan", "client_train", "uplink_encode", "fold",
            "finalize", "optimizer", "broadcast_encode",
            "feedback"} <= sync_names


def test_metrics_bytes_match_roundlog_bitwise():
    _, log, _, snap = _run_one_round(FLServer, enabled=True)
    assert snap["counters"]["fl.uplink_bytes"] == log.uplink_bytes
    assert snap["counters"]["fl.downlink_bytes"] == log.downlink_bytes
    assert snap["counters"]["ota.uplink_bytes"] == log.uplink_bytes
    assert snap["gauges"]["fl.n_participating"] == log.n_participating
    assert "ota.truncation_rate" in snap["gauges"]


def test_disabled_tracer_leaves_round_byte_identical():
    """Telemetry only observes: enabled vs disabled rounds produce
    bit-identical params and logs (spans/metrics never fork the math)."""
    srv_on, log_on, names_on, _ = _run_one_round(FLServer, enabled=True)
    srv_off, log_off, names_off, _ = _run_one_round(FLServer, enabled=False)
    assert names_on and not names_off
    assert log_on.uplink_bytes == log_off.uplink_bytes
    assert log_on.train_loss == log_off.train_loss
    for a, b in zip(jax.tree.leaves(srv_on.params),
                    jax.tree.leaves(srv_off.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_stream_round_log_publishes_stream_metrics():
    _, log, _, snap = _run_one_round(StreamingFLServer, enabled=True)
    assert snap["counters"]["stream.on_time"] == log.n_on_time
    assert snap["counters"]["stream.lost"] == log.n_lost
    assert snap["gauges"]["stream.sim_seconds"] == log.sim_seconds
