"""MoE execution-path selection + routing invariants."""
import jax.numpy as jnp
import numpy as np

from repro.models.layers import _route_local, moe_uses_shard_map


def _info(dp=16, mp=16):
    return {"sizes": {"data": dp, "model": mp}, "dp_axes": ("data",),
            "dp": dp, "mp": mp}


def test_path_selection():
    # kimi train_4k: T = 256*4096, E=384, K=8 -> shard_map
    assert moe_uses_shard_map(_info(), 384, 8, 256 * 4096)
    # kimi decode_32k: T = 128 tokens -> 8 per device * 8 = 64 < 384 -> local
    assert not moe_uses_shard_map(_info(), 384, 8, 128)
    # no mesh -> local
    assert not moe_uses_shard_map(None, 384, 8, 1 << 20)
    # indivisible experts -> local
    assert not moe_uses_shard_map(_info(mp=7), 384, 8, 1 << 20)
    # indivisible tokens -> local
    assert not moe_uses_shard_map(_info(dp=16), 384, 8, 100)


def test_route_local_invariants():
    rng = np.random.RandomState(0)
    T, d, E, K, C = 64, 16, 8, 2, 24
    xf = jnp.asarray(rng.randn(T, d).astype(np.float32))
    router = jnp.asarray(rng.randn(d, E).astype(np.float32))
    gate_vals, safe_expert, safe_rank, keep, aux = _route_local(xf, router, E, K, C)
    # gates normalised over K
    np.testing.assert_allclose(np.asarray(gate_vals.sum(-1)), 1.0, rtol=1e-5)
    # ranks within capacity for kept pairs; (expert, rank) unique
    se = np.asarray(safe_expert)
    sr = np.asarray(safe_rank)
    kp = np.asarray(keep)
    assert (sr[kp] < C).all()
    pairs = set()
    for e, r in zip(se[kp], sr[kp]):
        assert (e, r) not in pairs, "capacity slot double-booked"
        pairs.add((e, r))
    # aux loss ~ 1 for a near-balanced random router
    assert 0.5 < float(aux) < 3.0


def test_capacity_drops_are_worst_ranked():
    """Overflowing pairs (rank >= C) are dropped, never mis-routed."""
    rng = np.random.RandomState(1)
    T, d, E, K = 32, 8, 2, 1
    C = 4  # far below T*K/E = 16 -> most pairs dropped
    xf = jnp.asarray(rng.randn(T, d).astype(np.float32))
    router = jnp.asarray(rng.randn(d, E).astype(np.float32))
    _, safe_expert, safe_rank, keep, _ = _route_local(xf, router, E, K, C)
    kept = int(np.asarray(keep).sum())
    assert kept <= E * C
    assert kept > 0
