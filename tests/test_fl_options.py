"""FL robustness options: straggler dropout, FedProx, server momentum,
context/hardware drift triggers."""
import random

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.core.profiling.users import drift_device, drift_user, make_users
from repro.core.profiling.hardware import make_fleet
from repro.fl import FLServer


def _cfg(**kw):
    base = dict(
        n_clients=6,
        clients_per_round=3,
        n_rounds=2,
        local_steps=1,
        local_batch=2,
        lr=1e-3,
        planner="unified",
        seed=0,
    )
    base.update(kw)
    return FLConfig(**base)


def test_dropout_reduces_participation():
    srv = FLServer(_cfg(dropout_prob=0.99, seed=3), shard_size=6)
    log = srv.run_round(0)
    assert log.n_participating <= 1  # nearly everyone straggled


def test_dropout_all_skips_aggregation_safely():
    srv = FLServer(_cfg(dropout_prob=1.0), shard_size=6)
    before = jax.tree.leaves(srv.params)[0].copy()
    log = srv.run_round(0)
    assert log.n_participating == 0
    assert np.isnan(log.train_loss)  # NaN loss marks the skipped round
    after = jax.tree.leaves(srv.params)[0]
    np.testing.assert_array_equal(before, after)  # params untouched


def test_fedprox_shrinks_delta_norm():
    """The proximal term pulls local weights toward the global model, so
    the returned delta must be smaller in norm."""
    def delta_norm(mu):
        srv = FLServer(_cfg(fedprox_mu=mu, local_steps=4), shard_size=6)
        client = srv.clients[0]
        delta, _ = client.local_update(
            srv.params, 16, local_steps=4, local_batch=2, lr=5e-2, fedprox_mu=mu
        )
        return float(jnp.sqrt(sum(jnp.sum(x**2) for x in jax.tree.leaves(delta))))

    assert delta_norm(10.0) < delta_norm(0.0)


def test_server_momentum_accumulates():
    srv = FLServer(_cfg(server_momentum=0.9), shard_size=6)
    srv.run(2)
    assert hasattr(srv, "_velocity")
    vnorm = float(sum(jnp.sum(jnp.abs(v)) for v in jax.tree.leaves(srv._velocity)))
    assert vnorm > 0


def test_drift_changes_and_triggers():
    users = make_users(50, seed=0)
    rng = random.Random(0)
    changed = sum(drift_user(u, rng) for u in users for _ in range(3))
    assert changed > 0  # drift actually fires at these probabilities
    fleet = make_fleet(50, seed=0)
    hw_changed = sum(drift_device(s, rng) for s in fleet for _ in range(3))
    assert hw_changed > 0


def test_drift_tracked_by_server():
    srv = FLServer(_cfg(seed=5), shard_size=6)
    srv.run(2)
    assert hasattr(srv, "last_drift")
    nc, nh = srv.last_drift
    assert nc >= 0 and nh >= 0
