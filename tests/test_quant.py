"""Property tests for the quantization primitives (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare env: deterministic fallback sampler
    from _hypothesis_fallback import given, settings, st

from repro.core import quant

BITS = [4, 8, 16]


@st.composite
def arrays(draw, max_len=2000):
    n = draw(st.integers(8, max_len))
    seed = draw(st.integers(0, 2**16))
    scale = draw(st.floats(1e-3, 1e3))
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.randn(n).astype(np.float32) * scale)


@settings(deadline=None, max_examples=25)
@given(arrays(), st.sampled_from(BITS))
def test_fake_quant_error_bound(x, bits):
    """|x - fq(x)| <= scale/2 elementwise (round-to-nearest), except clips.

    fp32 slack: x/scale near half-integers rounds either way, and the
    division/multiplication each lose ~1 ulp of |x| — so the bound gets a
    half-scale relative term plus a few ulps of the tensor max.
    """
    q, scale = quant.quantize(x, bits)
    fq = quant.fake_quant(x, bits)
    qmax = quant.qrange(bits)
    amax = jnp.max(jnp.abs(x))
    inside = jnp.abs(x) <= qmax * scale
    err = jnp.abs(x - fq)
    bound = 0.5 * scale * (1 + 1e-3) + 4e-6 * amax + 1e-6
    assert jnp.all(jnp.where(inside, err <= bound, True))


@settings(deadline=None, max_examples=25)
@given(arrays(), st.sampled_from(BITS))
def test_quantize_range(x, bits):
    q, _ = quant.quantize(x, bits)
    qmax = quant.qrange(bits)
    assert int(jnp.max(jnp.abs(q))) <= qmax


@settings(deadline=None, max_examples=10)
@given(arrays(max_len=400), st.sampled_from([4, 8]))
def test_stochastic_rounding_unbiased(x, bits):
    """E[fq_stochastic(x)] == x (within CLT tolerance over repeats)."""
    keys = jax.random.split(jax.random.key(0), 64)
    fqs = jnp.stack([quant.fake_quant(x, bits, key=k) for k in keys])
    mean = jnp.mean(fqs, axis=0)
    _, scale = quant.quantize(x, bits)
    # Bernoulli rounding: per-sample var <= scale^2/4; mean of 64 draws
    # has std <= scale/16 -> 5 sigma bound
    tol = 5 * float(scale) / (2 * np.sqrt(64)) + 1e-6
    qmax = quant.qrange(bits)
    inside = jnp.abs(x) <= (qmax - 1) * scale
    assert float(jnp.max(jnp.where(inside, jnp.abs(mean - x), 0.0))) <= tol


def test_monotone_bits():
    """More bits => no larger RMS error."""
    x = jnp.asarray(np.random.RandomState(0).randn(4096).astype(np.float32))
    errs = [float(quant.quant_error(x, b)) for b in (4, 8, 16, 32)]
    assert errs == sorted(errs, reverse=True)
    assert errs[-1] == 0.0  # 32 bits is the identity


def test_tree_roundtrip():
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "b": [jnp.ones((3, 4)), jnp.linspace(-2, 2, 7)]}
    q, s = quant.quantize_tree(tree, 8)
    dq = quant.dequantize_tree(q, s, 8)
    for orig, rec in zip(jax.tree.leaves(tree), jax.tree.leaves(dq)):
        np.testing.assert_allclose(orig, rec, atol=float(jnp.max(jnp.abs(orig))) / 100)


def test_ste_gradient_is_identity():
    x = jnp.linspace(-1, 1, 64)
    g = jax.grad(lambda v: jnp.sum(quant.ste_fake_quant(v, 8) ** 2))(x)
    # gradient flows as if fq were identity: d/dx sum(fq(x)^2) = 2 fq(x)
    np.testing.assert_allclose(g, 2 * quant.fake_quant(x, 8), rtol=1e-5)
