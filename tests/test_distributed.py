"""Distributed-path tests: run in a subprocess with 8 forced host devices
(the main pytest process must keep 1 device for the rest of the suite;
see tests/_multidevice.py, the shared subprocess helper).

Covers: shard_map expert-parallel MoE == local math, a sharded train step
on the (data, model) mesh with the production param specs, and the
mesh-aware ``constrain`` helper.
"""

from _multidevice import run_multidevice as _run


def test_shard_map_moe_matches_local():
    print(_run("""
        import jax, jax.numpy as jnp
        from repro.configs import get_arch
        from repro.models import build_model
        from repro.models.layers import moe_block

        cfg = get_arch("kimi-k2-1t-a32b").reduced()
        m = build_model(cfg)
        params = m.init(jax.random.key(0))
        x = jax.random.normal(jax.random.key(2), (4, 16, cfg.d_model)) * 0.5
        moe_p = jax.tree.map(lambda a: a[0], params["layers"]["moe"])
        out_ref, _ = moe_block(moe_p, x, cfg)
        from repro.launch.mesh import make_mesh
        from repro.util import use_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        with use_mesh(mesh):
            out_sm, _ = jax.jit(lambda p_, x_: moe_block(p_, x_, cfg))(moe_p, x)
        err = float(jnp.abs(out_ref - out_sm).max())
        assert err < 1e-5, err
        print("moe shard_map equivalence ok", err)
    """))


def test_sharded_train_step_runs_and_matches_single_device():
    print(_run("""
        import jax, jax.numpy as jnp
        from repro.configs import get_arch
        from repro.models import build_model
        from repro.launch.steps import init_train_state, make_train_step
        from repro.launch import sharding as shd
        from repro.optim import adamw

        cfg = get_arch("qwen3-8b").reduced()
        model = build_model(cfg)
        opt = adamw(1e-3)
        state = init_train_state(model, opt, jax.random.key(0))
        batch = {
            "tokens": jax.random.randint(jax.random.key(1), (4, 64), 0, cfg.vocab_size)
        }
        # single-device reference
        ref_state, ref_metrics = jax.jit(make_train_step(model, opt))(state, batch)
        ref_loss = float(ref_metrics["loss"])

        from repro.launch.mesh import make_mesh
        from repro.util import use_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        state_shapes = jax.eval_shape(lambda: state)
        state_specs = {
            "params": shd.tree_param_specs(
                state_shapes["params"], mesh, n_kv_heads=cfg.n_kv_heads
            ),
            "opt": {
                k: shd.tree_param_specs(v, mesh, n_kv_heads=cfg.n_kv_heads)
                for k, v in state_shapes["opt"].items()
            },
            "step": jax.sharding.PartitionSpec(),
        }
        batch_specs = shd.batch_spec(
            {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in batch.items()}, mesh
        )
        with use_mesh(mesh):
            jitted = jax.jit(
                make_train_step(model, opt),
                in_shardings=(
                    shd.to_named(state_specs, mesh),
                    shd.to_named(batch_specs, mesh),
                ),
            )
            state2 = jax.device_put(state, shd.to_named(state_specs, mesh))
            batch2 = jax.device_put(batch, shd.to_named(batch_specs, mesh))
            new_state, metrics = jitted(state2, batch2)
            loss = float(metrics["loss"])
        assert abs(loss - ref_loss) < 1e-2, (loss, ref_loss)
        # params agree between single-device and sharded step
        diff = jax.tree.map(
            lambda a, b: float(jnp.max(jnp.abs(a - jax.device_get(b)))),
            ref_state["params"], jax.device_get(new_state["params"]))
        assert max(jax.tree.leaves(diff)) < 5e-2
        print("sharded train step ok", loss, ref_loss)
    """))


def test_constrain_filters_indivisible_dims():
    print(_run("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import make_mesh
        from repro.util import constrain, use_mesh

        mesh = make_mesh((2, 4), ("data", "model"))

        @jax.jit
        def f(x):
            # 7 doesn't divide 4 -> model entry must be dropped, not crash
            return constrain(x, P("data", "model")) * 2

        with use_mesh(mesh):
            out = f(jnp.ones((8, 7)))
        assert out.shape == (8, 7)
        print("constrain divisibility guard ok")
    """))


def test_use_mesh_global_setter_restores_previous(monkeypatch):
    """ROADMAP regression: on jax builds where ``jax.set_mesh`` is a bare
    global setter (not a context manager), nested/sequential ``use_mesh``
    blocks must restore the outer mesh on exit and clear it (None) at the
    outermost level — not leak the inner mesh into the process."""
    import jax

    from repro import util

    calls = []

    def fake_set_mesh(mesh):
        calls.append(mesh)
        return None  # global-setter variant: nothing context-manager-like

    monkeypatch.setattr(jax, "set_mesh", fake_set_mesh, raising=False)
    a, b = object(), object()
    with util.use_mesh(a):
        assert calls == [a]
        with util.use_mesh(b):
            assert calls == [a, b]
        # inner exit must re-activate the outer mesh, not leave b active
        assert calls == [a, b, a]
    # outermost exit clears the ambient mesh
    assert calls == [a, b, a, None]
    assert util._MESH_STACK == []


def test_multipod_mesh_axes():
    print(_run("""
        import jax
        from repro.launch.mesh import make_production_mesh
        # 8 fake devices can't fit 512; just verify axis naming contract
        try:
            make_production_mesh(multi_pod=True)
            raise SystemExit("should not fit on 8 devices")
        except ValueError:
            pass
        print("mesh contract ok")
    """))
