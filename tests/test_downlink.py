"""Compressed downlink broadcast + quantized server state (DESIGN.md §13).

Pins the symmetric-wire contract: the f32 passthrough downlink is
byte-identical to the legacy uncompressed broadcast (the equivalence
oracle), a quantized downlink reconstructs bit-identically across the
whole fleet from one shared encoded row, the quantization residual rides
the next broadcast (error feedback), and quantized optimizer state — bf16
first moments, blockwise-int8 second moments — tracks f32 within the
documented tolerances and survives a checkpoint round trip at its
compressed size.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as ckpt
from repro.configs.base import FLConfig
from repro.core import ota, packing, quant, wire
from repro.fl.server import FLServer
from repro.optim.optimizers import adam, momentum, state_nbytes


def _fl_cfg(**kw):
    base = dict(
        n_clients=4,
        clients_per_round=2,
        n_rounds=2,
        local_steps=1,
        local_batch=2,
        lr=1e-3,
        planner="unified",
        seed=0,
    )
    base.update(kw)
    return FLConfig(**base)


def _flat_params(srv):
    return np.concatenate(
        [np.asarray(l, np.float32).ravel() for l in jax.tree.leaves(srv.params)]
    )


# ---------------------------------------------------------------------------
# codec determinism: one encoded row, a whole fleet of identical decodes
# ---------------------------------------------------------------------------


def test_decode_is_deterministic_across_decoders():
    row_f32 = jnp.asarray(np.random.RandomState(0).randn(1000), jnp.float32)
    seed = ota.derive_dl_seed(jax.random.key(3))
    enc = wire.encode_row(row_f32, 8, seed, 0, block=64)
    decodes = [np.asarray(wire.decode_row(enc)) for _ in range(4)]
    for d in decodes[1:]:
        np.testing.assert_array_equal(decodes[0], d)
    # decoding a byte-copy of the row agrees too (what a client receives)
    copy = packing.PackedRow(
        data=jnp.asarray(np.asarray(enc.data).copy()),
        scale=jnp.asarray(np.asarray(enc.scale).copy()),
        bits=enc.bits,
        qblock=enc.qblock,
    )
    np.testing.assert_array_equal(decodes[0], np.asarray(wire.decode_row(copy)))


def test_encode_row_uses_disjoint_downlink_stream():
    key = jax.random.key(9)
    assert int(ota.derive_dl_seed(key)) != int(ota.derive_sr_seed(key))
    row = jnp.asarray(np.random.RandomState(1).randn(512), jnp.float32)
    up = wire.encode_row(row, 4, ota.derive_sr_seed(key), 0)
    down = wire.encode_row(row, 4, ota.derive_dl_seed(key), 0)
    assert not np.array_equal(np.asarray(up.data), np.asarray(down.data))


def test_decode_broadcast_quantized_needs_base():
    row = jnp.asarray(np.random.RandomState(2).randn(256), jnp.float32)
    enc = wire.encode_row(row, 8, jnp.uint32(5), 0)
    with pytest.raises(AssertionError):
        wire.decode_broadcast(enc, None)
    base = jnp.zeros(256, jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(wire.decode_broadcast(enc, base)),
        np.asarray(wire.decode_row(enc)),
    )


def test_blockwise_downlink_mse_le_per_row():
    rng = np.random.RandomState(3)
    # heterogeneous magnitudes: the case blockwise scales exist for
    row = jnp.asarray(
        np.concatenate([rng.randn(512) * s for s in (1e-3, 1e-1, 10.0)]),
        jnp.float32,
    )
    seed = jnp.uint32(11)
    for bits in (4, 8):
        per = wire.decode_row(wire.encode_row(row, bits, seed, 0))
        blk = wire.decode_row(wire.encode_row(row, bits, seed, 0, block=256))
        e_per = float(jnp.mean((per - row) ** 2))
        e_blk = float(jnp.mean((blk - row) ** 2))
        assert e_blk <= e_per, (bits, e_blk, e_per)


# ---------------------------------------------------------------------------
# f32 passthrough: byte-identical to the legacy uncompressed broadcast
# ---------------------------------------------------------------------------


class _LegacyServer(FLServer):
    """Pre-§13 apply/broadcast: per-leaf tree.map, no wire codec."""

    def _apply_update(self, agg, round_key):
        if self.cfg.server_momentum > 0.0:
            if not hasattr(self, "_legacy_velocity"):
                self._legacy_velocity = jax.tree.map(
                    lambda u: jnp.zeros_like(u, jnp.float32), agg
                )
            self._legacy_velocity = jax.tree.map(
                lambda v, u: self.cfg.server_momentum * v + u,
                self._legacy_velocity,
                agg,
            )
            agg = self._legacy_velocity
        self.params = jax.tree.map(
            lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
            self.params,
            agg,
        )


@pytest.mark.parametrize("server_momentum", [0.0, 0.9])
def test_f32_passthrough_bit_identical_to_legacy(server_momentum):
    cfg = _fl_cfg(server_momentum=server_momentum)
    new = FLServer(cfg, shard_size=4)
    old = _LegacyServer(cfg, shard_size=4)
    for r in range(2):
        new.run_round(r)
        old.run_round(r)
        for a, b in zip(jax.tree.leaves(new.params), jax.tree.leaves(old.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the broadcast row IS the uncoded f32 params: exactly 4 bytes/symbol
    row = new.last_broadcast
    assert row.kind == "float32"
    assert row.wire_nbytes == 4 * new.layout.padded_size
    np.testing.assert_array_equal(
        np.asarray(wire.decode_broadcast(row)),
        np.asarray(packing.pack(new.params, new.layout)),
    )


# ---------------------------------------------------------------------------
# quantized downlink: fleet-wide bit-identity + error feedback
# ---------------------------------------------------------------------------


def test_quantized_downlink_fleet_reconstructs_bit_identical():
    srv = FLServer(_fl_cfg(downlink_bits=4, downlink_block=256), shard_size=4)
    base = np.asarray(srv._bcast)  # every client's replica before round 0
    srv.run_round(0)
    row = srv.last_broadcast
    assert row.kind == "int4"
    assert row.wire_nbytes == srv.last_downlink_bytes
    assert srv.round_logs[-1].downlink_bytes == row.wire_nbytes
    assert row.wire_nbytes < 4 * srv.layout.padded_size / 7
    # N independent client decodes of the one broadcast row
    recon = [
        np.asarray(wire.decode_broadcast(row, jnp.asarray(base))) for _ in range(3)
    ]
    for r in recon[1:]:
        np.testing.assert_array_equal(recon[0], r)
    # ... and the server adopted the same reconstruction as its params
    np.testing.assert_array_equal(
        recon[0], np.asarray(packing.pack(srv.params, srv.layout))
    )


def test_quantized_downlink_error_feedback_residual():
    srv = FLServer(_fl_cfg(downlink_bits=8), shard_size=4)
    srv.run_round(0)
    residual = np.asarray(srv._master - srv._bcast)
    assert np.any(residual != 0)  # quantization left something behind
    # the next broadcast ships master - bcast: the residual rides along
    base = np.asarray(srv._bcast)
    srv.run_round(1)
    np.testing.assert_array_equal(
        np.asarray(wire.decode_broadcast(srv.last_broadcast, jnp.asarray(base))),
        np.asarray(srv._bcast),
    )
    # the fleet replica stays close to the master it tracks
    master = np.asarray(srv._master)
    err = np.linalg.norm(np.asarray(srv._bcast) - master)
    assert err <= 1e-2 * max(np.linalg.norm(master), 1e-12)


def test_quantized_downlink_run_close_to_f32():
    cfg32 = _fl_cfg(seed=1)
    cfg8 = _fl_cfg(seed=1, downlink_bits=8)
    s32 = FLServer(cfg32, shard_size=4)
    s8 = FLServer(cfg8, shard_size=4)
    for r in range(2):
        s32.run_round(r)
        s8.run_round(r)
    a, b = _flat_params(s32), _flat_params(s8)
    assert np.linalg.norm(a - b) <= 1e-2 * np.linalg.norm(a)
    assert s8.round_logs[-1].downlink_bytes < s32.round_logs[-1].downlink_bytes / 3


# ---------------------------------------------------------------------------
# quantized server state (bf16 velocity / quantized moments)
# ---------------------------------------------------------------------------


def test_quantized_server_momentum_within_1pct_and_half_bytes():
    base = dict(seed=2, server_momentum=0.9)
    f32 = FLServer(_fl_cfg(**base), shard_size=4)
    q = FLServer(_fl_cfg(**base, quantize_server_state=True), shard_size=4)
    for r in range(2):
        f32.run_round(r)
        q.run_round(r)
    a, b = _flat_params(f32), _flat_params(q)
    assert np.linalg.norm(a - b) <= 1e-2 * np.linalg.norm(a)
    assert q.server_state_nbytes > 0
    assert q.server_state_nbytes <= 0.5 * f32.server_state_nbytes
    assert q._velocity.dtype == jnp.bfloat16


def test_quantized_adam_tracks_f32():
    rng = np.random.RandomState(4)
    params = {"w": jnp.asarray(rng.randn(600), jnp.float32)}
    o32, oq = adam(1e-2), adam(1e-2, quantize=True)
    s32, sq = o32.init(params), oq.init(params)
    p32 = pq = params
    for step in range(5):
        g = {"w": p32["w"] * 0.1 + jnp.asarray(rng.randn(600) * 0.01, jnp.float32)}
        u32, s32 = o32.update(g, s32, p32, jnp.asarray(step))
        uq, sq = oq.update(g, sq, pq, jnp.asarray(step))
        p32 = jax.tree.map(lambda p, u: p + u, p32, u32)
        pq = jax.tree.map(lambda p, u: p + u, pq, uq)
    diff = float(jnp.linalg.norm(p32["w"] - pq["w"]))
    assert diff <= 1e-2 * float(jnp.linalg.norm(p32["w"]))
    assert state_nbytes(sq) <= 0.5 * state_nbytes(s32)


def test_quantize_state_roundtrip_error_bounded():
    rng = np.random.RandomState(5)
    x = jnp.asarray(np.abs(rng.randn(1000)) * 1e-4, jnp.float32)
    q, scale = quant.quantize_state(x)
    back = quant.dequantize_state(q, scale)
    assert q.dtype == jnp.int8
    # round-to-nearest on the amax grid: error <= scale/2 per block
    cols = np.repeat(np.asarray(scale), quant.STATE_BLOCK)[: x.shape[0]]
    assert np.all(np.abs(np.asarray(back - x)) <= cols / 2 + 1e-12)


# ---------------------------------------------------------------------------
# checkpointing quantized state
# ---------------------------------------------------------------------------


def test_ckpt_native_bf16_half_bytes_and_bit_identical(tmp_path):
    x = jnp.asarray(np.random.RandomState(6).randn(333), jnp.bfloat16)
    _, leaves = ckpt._pack_tree({"m": x})
    assert leaves[0]["dtype"] == "bf16n"
    assert len(leaves[0]["data"]) == 2 * x.size  # native, not widened f32
    p = str(tmp_path / "ck.msgpack.zst")
    ckpt.save_checkpoint(p, {"m": x})
    got, _ = ckpt.load_checkpoint(p)
    assert got["m"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(x).view(np.uint16), np.asarray(got["m"]).view(np.uint16)
    )


def test_ckpt_legacy_bf16_tag_still_readable():
    arr = np.arange(4, dtype=np.float32)
    structure = {"t": "__leaf__", "v": 0}
    leaves = [{"dtype": "bf16", "shape": [4], "data": arr.tobytes()}]
    got = ckpt._unpack_tree(structure, leaves)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(got, np.float32), arr)


@pytest.mark.parametrize("opt", ["momentum", "adam"])
def test_ckpt_roundtrip_quantized_optimizer_state(tmp_path, opt):
    rng = np.random.RandomState(7)
    params = {"w": jnp.asarray(rng.randn(300), jnp.float32)}
    if opt == "momentum":
        o = momentum(1e-2, quantize=True)
    else:
        o = adam(1e-2, quantize=True)
    state = o.init(params)
    g = {"w": jnp.asarray(rng.randn(300), jnp.float32)}
    _, state = o.update(g, state, params, jnp.asarray(0))
    p = str(tmp_path / "opt.msgpack.zst")
    ckpt.save_checkpoint(p, state)
    got, _ = ckpt.load_checkpoint(p)
    assert state_nbytes(got) == state_nbytes(state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(got)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32)
        )


# ---------------------------------------------------------------------------
# AggregateInfo: typed, but still a Mapping for legacy info["..."] access
# ---------------------------------------------------------------------------


def test_aggregate_info_mapping_shim():
    rng = np.random.RandomState(8)
    ups = [{"w": jnp.asarray(rng.randn(256), jnp.float32)} for _ in range(3)]
    layout = packing.make_layout(ups[0])
    X = packing.pack_batch(ups, layout)
    bits = [8, 8, 4]
    rows = wire.encode_rows(list(X), bits, ota.derive_sr_seed(jax.random.key(0)))
    _, info = ota.ota_aggregate_packed(
        jax.random.key(0),
        rows,
        bits,
        [1.0, 1.0, 1.0],
        layout,
        ota.OTAConfig(snr_db=20.0),
    )
    assert isinstance(info, ota.AggregateInfo)
    assert info["uplink_bytes"] == info.uplink_bytes > 0
    assert "noise_std" in info and "downlink_bytes" not in info  # None: absent
    d = dict(info)
    assert d["n_participating"] == info.n_participating
    info.downlink_bytes = 123
    assert info["downlink_bytes"] == 123 and len(info) == len(d) + 1
