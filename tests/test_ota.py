"""OTA aggregation behaviour: unbiasedness, fade truncation, SNR scaling."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ota


def _updates(n, shape=(500,), seed=0):
    rng = np.random.RandomState(seed)
    return [{"w": jnp.asarray(rng.randn(*shape).astype(np.float32))} for _ in range(n)]


def test_high_snr_high_bits_recovers_weighted_mean():
    ups = _updates(5)
    weights = [1.0, 2.0, 1.0, 0.5, 1.5]
    agg, info = ota.ota_aggregate(
        jax.random.key(0), ups, [32] * 5, weights, ota.OTAConfig(snr_db=80.0)
    )
    # compute expected weighted mean over PARTICIPATING clients
    mask = info["participation"]
    w = np.array(weights) * np.array(mask, float)
    w = w / w.sum()
    want = sum(wi * np.asarray(u["w"]) for wi, u in zip(w, ups))
    np.testing.assert_allclose(np.asarray(agg["w"]), want, rtol=1e-3, atol=1e-3)


def test_fade_truncation_excludes_clients():
    # with many clients, some should hit the fade threshold
    ups = _updates(64)
    agg, info = ota.ota_aggregate(
        jax.random.key(1), ups, [8] * 64, [1.0] * 64, ota.OTAConfig()
    )
    assert 0 < info["n_participating"] <= 64
    # Rayleigh |h|^2 ~ Exp(1): P(<0.1) ~ 9.5%; expect a few excluded
    assert info["n_participating"] < 64


def test_lower_snr_more_noise():
    ups = _updates(4)
    outs = {}
    for snr in (40.0, 0.0):
        agg, _ = ota.ota_aggregate(
            jax.random.key(2), ups, [32] * 4, [1.0] * 4, ota.OTAConfig(snr_db=snr)
        )
        clean, _ = ota.ota_aggregate(
            jax.random.key(2), ups, [32] * 4, [1.0] * 4, ota.OTAConfig(snr_db=200.0)
        )
        outs[snr] = float(jnp.linalg.norm(agg["w"] - clean["w"]))
    assert outs[0.0] > outs[40.0] > 0


def test_mixed_precision_unbiased_expectation():
    """Stochastic rounding makes low-bit aggregation unbiased in expectation."""
    ups = _updates(3, shape=(200,))
    mean = np.zeros(200, np.float32)
    R = 48
    for i in range(R):
        agg, _ = ota.ota_aggregate(
            jax.random.key(100 + i),
            ups,
            [4, 8, 16],
            [1.0] * 3,
            ota.OTAConfig(snr_db=60.0, fade_threshold=0.0),
        )
        # fade may exclude clients; use unfiltered config via threshold 0.0
        mean += np.asarray(agg["w"]) / R
    # expectation should approach SOME weighted mean of the participating
    # sets; with threshold 0 nobody is excluded:
    want = np.mean([np.asarray(u["w"]) for u in ups], axis=0)
    err = np.abs(mean - want).max()
    assert err < 0.15, err


def _mixed_updates(n, seed=7):
    rng = np.random.RandomState(seed)
    return [
        {
            "w": jnp.asarray(rng.randn(40, 13).astype(np.float32)),
            "b": [
                jnp.asarray(rng.randn(77).astype(np.float32)),
                jnp.asarray(rng.randn(3, 5, 2).astype(np.float32)),
            ],
        }
        for _ in range(n)
    ]


def test_flat_path_matches_pertree_oracle():
    """The fused flat pipeline == the legacy per-tree loop, same keys."""
    ups = _mixed_updates(6)
    bits = [4, 8, 16, 32, 8, 4]
    weights = [1.0, 2.0, 0.5, 1.0, 3.0, 1.5]
    for snr in (80.0, 15.0):
        cfg = ota.OTAConfig(snr_db=snr)
        key = jax.random.key(123)
        flat, info_f = ota.ota_aggregate(key, ups, bits, weights, cfg)
        tree, info_t = ota.ota_aggregate_pertree(key, ups, bits, weights, cfg)
        assert jax.tree.structure(flat) == jax.tree.structure(tree)
        for a, b in zip(jax.tree.leaves(flat), jax.tree.leaves(tree)):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5
            )
        assert info_f["participation"] == info_t["participation"]
        assert abs(info_f["noise_std"] - info_t["noise_std"]) < 1e-6


def test_fused_kernel_matches_jnp_reference_path():
    """interpret-mode Pallas kernel == the fused jnp reference, bit-for-bit
    semantics (same uniforms, same grid)."""
    ups = _mixed_updates(5, seed=11)
    bits = [4, 16, 8, 32, 4]
    weights = [1.0] * 5
    key = jax.random.key(9)
    cfg = ota.OTAConfig(snr_db=30.0)
    a_jnp, _ = ota.ota_aggregate(key, ups, bits, weights, cfg, use_kernel=False)
    a_ker, _ = ota.ota_aggregate(key, ups, bits, weights, cfg, use_kernel=True)
    for a, b in zip(jax.tree.leaves(a_jnp), jax.tree.leaves(a_ker)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6)


def test_flat_stochastic_rounding_unbiased_over_keys():
    """E[aggregate] -> true weighted mean as rounds accumulate (the OTA
    guarantee stochastic rounding buys)."""
    ups = _mixed_updates(3, seed=3)
    weights = [1.0, 1.0, 1.0]
    cfg = ota.OTAConfig(snr_db=70.0, fade_threshold=0.0)
    R = 64
    acc = None
    for i in range(R):
        agg, _ = ota.ota_aggregate(
            jax.random.key(5000 + i), ups, [4, 4, 8], weights, cfg
        )
        flat = jnp.concatenate([l.reshape(-1) for l in jax.tree.leaves(agg)])
        acc = flat / R if acc is None else acc + flat / R
    want = np.mean(
        [
            np.concatenate([np.asarray(l).reshape(-1) for l in jax.tree.leaves(u)])
            for u in ups
        ],
        axis=0,
    )
    # 4-bit shared-grid scale ~ amax/7; mean-of-R rounding noise ~ scale/2/sqrt(R)
    err = float(jnp.abs(acc - want).max())
    assert err < 0.12, err


def test_packed_entrypoint_matches_pytree_entrypoint():
    from repro.core import packing

    ups = _mixed_updates(4, seed=19)
    bits = [8, 8, 4, 16]
    weights = [1.0, 0.5, 2.0, 1.0]
    lay = packing.make_layout(ups[0])
    X = packing.pack_batch(ups, lay)
    key = jax.random.key(77)
    via_tree, info_a = ota.ota_aggregate(key, ups, bits, weights)
    via_packed, info_b = ota.ota_aggregate_packed(key, X, bits, weights, lay)
    for a, b in zip(jax.tree.leaves(via_tree), jax.tree.leaves(via_packed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert info_a["noise_std"] == info_b["noise_std"]


def test_channel_uses_constant_in_clients():
    """The OTA property: channel uses don't scale with #clients."""
    assert ota.channel_uses([4, 8, 16, 32], 1000) == 1000
    assert ota.channel_uses([8], 1000) == 1000
    assert ota.digital_uplink_bits([8, 8], 1000) == 16000
