"""OTA aggregation behaviour: unbiasedness, fade truncation, SNR scaling."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ota, quant


def _updates(n, shape=(500,), seed=0):
    rng = np.random.RandomState(seed)
    return [{"w": jnp.asarray(rng.randn(*shape).astype(np.float32))}
            for _ in range(n)]


def test_high_snr_high_bits_recovers_weighted_mean():
    ups = _updates(5)
    weights = [1.0, 2.0, 1.0, 0.5, 1.5]
    agg, info = ota.ota_aggregate(
        jax.random.key(0), ups, [32] * 5, weights,
        ota.OTAConfig(snr_db=80.0))
    # compute expected weighted mean over PARTICIPATING clients
    mask = info["participation"]
    w = np.array(weights) * np.array(mask, float)
    w = w / w.sum()
    want = sum(wi * np.asarray(u["w"]) for wi, u in zip(w, ups))
    np.testing.assert_allclose(np.asarray(agg["w"]), want, rtol=1e-3, atol=1e-3)


def test_fade_truncation_excludes_clients():
    # with many clients, some should hit the fade threshold
    ups = _updates(64)
    agg, info = ota.ota_aggregate(
        jax.random.key(1), ups, [8] * 64, [1.0] * 64, ota.OTAConfig())
    assert 0 < info["n_participating"] <= 64
    # Rayleigh |h|^2 ~ Exp(1): P(<0.1) ~ 9.5%; expect a few excluded
    assert info["n_participating"] < 64


def test_lower_snr_more_noise():
    ups = _updates(4)
    outs = {}
    for snr in (40.0, 0.0):
        agg, _ = ota.ota_aggregate(jax.random.key(2), ups, [32] * 4,
                                   [1.0] * 4, ota.OTAConfig(snr_db=snr))
        clean, _ = ota.ota_aggregate(jax.random.key(2), ups, [32] * 4,
                                     [1.0] * 4, ota.OTAConfig(snr_db=200.0))
        outs[snr] = float(jnp.linalg.norm(agg["w"] - clean["w"]))
    assert outs[0.0] > outs[40.0] > 0


def test_mixed_precision_unbiased_expectation():
    """Stochastic rounding makes low-bit aggregation unbiased in expectation."""
    ups = _updates(3, shape=(200,))
    mean = np.zeros(200, np.float32)
    R = 48
    for i in range(R):
        agg, _ = ota.ota_aggregate(
            jax.random.key(100 + i), ups, [4, 8, 16], [1.0] * 3,
            ota.OTAConfig(snr_db=60.0, fade_threshold=0.0))
        # fade may exclude clients; use unfiltered config via threshold 0.0
        mean += np.asarray(agg["w"]) / R
    # expectation should approach SOME weighted mean of the participating
    # sets; with threshold 0 nobody is excluded:
    want = np.mean([np.asarray(u["w"]) for u in ups], axis=0)
    err = np.abs(mean - want).max()
    assert err < 0.15, err


def test_channel_uses_constant_in_clients():
    """The OTA property: channel uses don't scale with #clients."""
    assert ota.channel_uses([4, 8, 16, 32], 1000) == 1000
    assert ota.channel_uses([8], 1000) == 1000
    assert ota.digital_uplink_bits([8, 8], 1000) == 16000
