"""Streaming aggregation (DESIGN.md §11): the fold kernel/oracle pair,
the persistent ``OtaAccumulator``, the ``plan_stream`` round planner,
the ``LatencyModel`` arrival simulation, and the ``StreamingFLServer``
round loop — including its equivalence oracle: no deadline + identical
arrival set => bit-identical to the synchronous ``FLServer``."""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FLConfig
from repro.core import ota, packing
from repro.core.profiling.hardware import make_fleet
from repro.fl import FLServer, LatencyModel, StreamingFLServer, plan_stream
from repro.kernels import ops as kops
from repro.kernels import ref as kref

M = 4096
K = 5


def _rows(bits_list, block=0, seed=0):
    """Packed cohort rows (one flat leaf, quantized at the edge)."""
    rng = np.random.RandomState(seed)
    tree = {"w": jnp.zeros((M,), jnp.float32)}
    layout = packing.make_layout(tree)
    key = jax.random.key(seed + 5)
    sr = ota.derive_sr_seed(key)
    rows = []
    for i, b in enumerate(bits_list):
        up = {"w": jnp.asarray(rng.randn(M).astype(np.float32) * 0.01)}
        rows.append(ota.quantize_uplink(packing.pack(up, layout), b, sr, i,
                                        block=block))
    return rows, layout, key


# ---------------------------------------------------------------------------
# fold kernel == oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bits,block", [
    (4, 0), (4, packing.QUANT_BLOCK), (8, packing.QUANT_BLOCK),
    (16, 0), (32, 0),
])
def test_fold_kernel_matches_oracle(bits, block):
    rows, layout, _ = _rows([bits] * K, block=block)
    kinds, datas, scales, _ = ota._group_rows(rows)
    assert len(kinds) == 1
    (kind, qblock), data, scale = kinds[0], datas[0], scales[0]
    rng = np.random.RandomState(7)
    acc = jnp.asarray(rng.randn(layout.padded_size).astype(np.float32))
    w = jnp.asarray(rng.rand(K).astype(np.float32))
    packed4 = kind == "int4"
    got = kops.ota_fold_packed(acc, data, scale, w, qblock=qblock,
                               packed4=packed4)
    want = kref.ota_fold_ref(acc, data, scale, w, qblock=qblock,
                             packed4=packed4)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_fold_zero_acc_equals_barrier():
    rows, layout, _ = _rows([8] * K, block=packing.QUANT_BLOCK)
    kinds, datas, scales, _ = ota._group_rows(rows)
    (kind, qblock), data, scale = kinds[0], datas[0], scales[0]
    w = jnp.linspace(0.1, 1.0, K, dtype=jnp.float32)
    zeros = jnp.zeros((layout.padded_size,), jnp.float32)
    fold = kops.ota_fold_packed(zeros, data, scale, w, qblock=qblock)
    barrier = kops.ota_dequant_superpose(data, scale, w, qblock=qblock)
    np.testing.assert_array_equal(np.asarray(fold), np.asarray(barrier))


# ---------------------------------------------------------------------------
# staleness discount
# ---------------------------------------------------------------------------


def test_staleness_weights():
    w = np.asarray(ota.staleness_weights([0.0, 1.0, 2.0, 5.0], 2.0,
                                         gamma=0.5))
    assert w[0] == 1.0                       # at the trigger: full weight
    np.testing.assert_allclose(w[1], 0.5 ** 0.5, rtol=1e-6)
    np.testing.assert_allclose(w[2], 0.5)    # end of grace: gamma
    np.testing.assert_allclose(w[3], 0.5)    # clipped, never below gamma
    assert np.all(np.diff(w) <= 0)


# ---------------------------------------------------------------------------
# OtaAccumulator
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("use_kernel", [False, True])
def test_accumulator_bit_equal_to_one_shot(use_kernel):
    """One-batch fold in cohort order == ota_aggregate_packed, bitwise."""
    rows, layout, key = _rows([4, 8, 8, 16, 32], block=packing.QUANT_BLOCK)
    weights = [1.0 + (i % 3) for i in range(K)]
    cfg = ota.OTAConfig(snr_db=20.0)
    ref, ref_info = ota.ota_aggregate_packed(key, rows, None, weights,
                                             layout, cfg,
                                             use_kernel=use_kernel)
    _, _, w = ota.round_channel(key, jnp.asarray(weights, jnp.float32),
                                cfg=cfg)
    acc = ota.OtaAccumulator(layout, cfg, use_kernel=use_kernel)
    got, info = acc.fold(rows, w).finalize(key)
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert info["n_folded"] == K
    assert info["uplink_bytes"] == ref_info["uplink_bytes"]


def test_accumulator_two_wave_fold_and_reset():
    rows, layout, key = _rows([4, 8, 8, 16, 32], block=packing.QUANT_BLOCK)
    cfg = ota.OTAConfig(snr_db=20.0)
    _, _, w = ota.round_channel(key, jnp.ones((K,), jnp.float32), cfg=cfg)
    acc = ota.OtaAccumulator(layout, cfg)
    acc.fold(rows[:3], w[:3])
    acc.fold(rows[3:], w[3:], staleness=[0.7, 0.5])
    assert acc.n_folded == K
    assert acc.wire_bytes == sum(r.wire_nbytes for r in rows)
    agg, info = acc.finalize(key)
    assert all(np.isfinite(np.asarray(x)).all()
               for x in jax.tree.leaves(agg))
    assert info["n_folded"] == K
    acc.reset()
    assert acc.n_folded == 0
    np.testing.assert_array_equal(np.asarray(acc.accumulator), 0.0)


# ---------------------------------------------------------------------------
# plan_stream
# ---------------------------------------------------------------------------


def test_plan_stream_all_on_time():
    p = plan_stream([3.0, 1.0, 2.0], fill=3)
    assert p.on_time == (0, 1, 2) and not p.late and not p.lost
    assert p.t_trigger == 3.0 and p.t_close == 3.0
    assert p.counted == (0, 1, 2)


def test_plan_stream_fill_triggers_early():
    p = plan_stream([1.0, 2.0, 10.0, 3.0], fill=2)
    assert p.t_trigger == 2.0
    assert p.on_time == (0, 1) and p.lost == (2, 3)


def test_plan_stream_deadline_fires_with_partial_cohort():
    p = plan_stream([1.0, 2.0, 10.0, 20.0], fill=4, deadline=5.0)
    assert p.t_trigger == 5.0
    assert p.on_time == (0, 1) and p.lost == (2, 3) and not p.late
    assert p.t_close == 5.0


def test_plan_stream_grace_window_and_staleness():
    p = plan_stream([1.0, 2.0, 3.0, 4.0, 9.0], fill=2, grace=2.0,
                    gamma=0.5)
    assert p.t_trigger == 2.0
    assert p.on_time == (0, 1) and p.late == (2, 3) and p.lost == (4,)
    np.testing.assert_allclose(p.staleness, [0.5 ** 0.5, 0.5], rtol=1e-6)
    assert p.t_close == 4.0  # the last counted late arrival ends the round


def test_plan_stream_unreachable_fill_degenerates_to_barrier():
    # fill target above the finite arrivals, no deadline: the plan falls
    # back to the synchronous barrier at the last finite arrival
    p = plan_stream([1.0, 5.0, math.inf], fill=3)
    assert p.t_trigger == 5.0
    assert p.on_time == (0, 1) and p.lost == (2,)


def test_plan_stream_everyone_dropped():
    p = plan_stream([math.inf, math.inf], fill=2, deadline=4.0)
    assert not p.on_time and not p.late and p.lost == (0, 1)
    assert p.t_trigger == 4.0 and p.counted == ()


# ---------------------------------------------------------------------------
# LatencyModel
# ---------------------------------------------------------------------------


def test_latency_model_deterministic_and_tailed():
    lat = LatencyModel.with_tail(5.0)
    np.testing.assert_allclose(lat.p95_over_p50(), 5.0, rtol=1e-3)
    spec = make_fleet(1, seed=0)[0]
    rng_a, rng_b = np.random.RandomState(3), np.random.RandomState(3)
    a = [lat.sample(spec, rng_a, uplink_bytes=1 << 16) for _ in range(2)]
    b = [lat.sample(spec, rng_b, uplink_bytes=1 << 16) for _ in range(2)]
    assert a == b and a[0] != a[1]  # seeded replay, fresh draws


def test_latency_model_low_battery_doubles_dropout():
    lat = LatencyModel(drop_prob=0.4)
    spec = make_fleet(1, seed=0)[0]
    normal = dataclasses.replace(spec, power_state="normal")
    low = dataclasses.replace(spec, power_state="low_battery")
    n = 4000
    rng = np.random.RandomState(0)
    d_norm = sum(lat.dropped(normal, rng) for _ in range(n)) / n
    d_low = sum(lat.dropped(low, rng) for _ in range(n)) / n
    assert 0.35 < d_norm < 0.45 and 0.75 < d_low < 0.85
    assert not LatencyModel().dropped(normal, rng)  # drop_prob=0: never


# ---------------------------------------------------------------------------
# StreamingFLServer
# ---------------------------------------------------------------------------


def _cfg(**kw):
    base = dict(n_clients=6, clients_per_round=3, n_rounds=2, local_steps=1,
                local_batch=2, lr=1e-3, planner="unified", seed=0)
    base.update(kw)
    return FLConfig(**base)


def test_streaming_matches_sync_bitwise():
    """No deadline, full fill, no latency dropouts: the buffered engine
    and the synchronous barrier run the same float ops in the same order
    => bit-identical global parameters (the §11 equivalence oracle)."""
    sync = FLServer(_cfg(), shard_size=4)
    stream = StreamingFLServer(_cfg(), shard_size=4)
    for r in range(2):
        la = sync.run_round(r)
        lb = stream.run_round(r)
        assert lb.n_late == 0 and lb.n_lost == 0
        assert la.train_loss == lb.train_loss
    for a, b in zip(jax.tree.leaves(sync.params),
                    jax.tree.leaves(stream.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_streaming_everyone_lost_skips_aggregation():
    srv = StreamingFLServer(_cfg(), shard_size=4,
                            latency=LatencyModel(drop_prob=1.0))
    before = jax.tree.leaves(srv.params)[0].copy()
    log = srv.run_round(0)
    assert log.n_participating == 0 and log.n_lost == 3
    assert np.isnan(log.train_loss)
    np.testing.assert_array_equal(before, jax.tree.leaves(srv.params)[0])


def test_streaming_deadline_fires_with_partial_cohort():
    """A deadline between the first and last arrival aggregates a strict
    subset of the cohort and still moves the model."""
    lat = LatencyModel.with_tail(3.0)
    probe = StreamingFLServer(_cfg(), shard_size=4, latency=lat)
    probe.run_round(0)
    times = sorted(probe.last_times)  # same seed => same arrival draws
    assert len(times) == 3 and all(map(math.isfinite, times))
    deadline = (times[0] + times[2]) / 2
    srv = StreamingFLServer(_cfg(), shard_size=4, latency=lat,
                            deadline_s=deadline, grace_s=0.0)
    before = jax.tree.leaves(srv.params)[0].copy()
    log = srv.run_round(0)
    assert 1 <= log.n_on_time < 3 and log.n_lost >= 1 and log.n_late == 0
    assert log.n_on_time + log.n_lost == 3
    assert log.sim_seconds == deadline
    assert np.isfinite(log.train_loss)
    assert not np.array_equal(before, jax.tree.leaves(srv.params)[0])
