"""int4 packing + int4 qmatmul path."""
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare env: deterministic fallback sampler
    from _hypothesis_fallback import given, settings, st

from repro.kernels import ops
from repro.kernels.ops import (
    pack_int4,
    qmatmul_int4,
    quantize_weights_int4,
    unpack_int4,
)


@settings(deadline=None, max_examples=20)
@given(st.integers(1, 64), st.integers(1, 64), st.integers(0, 2**16))
def test_pack_unpack_roundtrip(kh, n, seed):
    rng = np.random.RandomState(seed)
    q = jnp.asarray(rng.randint(-8, 8, size=(2 * kh, n)), jnp.int8)
    assert jnp.array_equal(unpack_int4(pack_int4(q)), q)


def test_packed_is_half_size():
    q = jnp.zeros((128, 64), jnp.int8)
    assert pack_int4(q).nbytes == q.nbytes // 2


def test_qmatmul_int4_matches_dequant():
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(32, 128).astype(np.float32))
    w = jnp.asarray(rng.randn(128, 64).astype(np.float32))
    packed, scale = quantize_weights_int4(w)
    got = qmatmul_int4(x, packed, scale)
    w_deq = unpack_int4(packed).astype(jnp.float32) * scale[None, :]
    want = x @ w_deq
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_int4_error_larger_than_int8():
    rng = np.random.RandomState(1)
    x = jnp.asarray(rng.randn(16, 64).astype(np.float32))
    w = jnp.asarray(rng.randn(64, 32).astype(np.float32))
    exact = x @ w
    q8, s8 = ops.quantize_weights(w, 8)
    e8 = float(jnp.abs(ops.qmatmul(x, q8, s8) - exact).mean())
    p4, s4 = quantize_weights_int4(w)
    e4 = float(jnp.abs(qmatmul_int4(x, p4, s4) - exact).mean())
    assert e4 > e8 > 0
