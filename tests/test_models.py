"""Model-zoo correctness: per-arch smoke tests (reduced variants), numeric
equivalences (chunked attention vs naive, chunked SSM scans vs sequential),
prefill/decode consistency, CTC vs brute force."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.configs.all_archs import ASSIGNED_ARCHS
from repro.models import build_model
from repro.models import layers as L
from repro.models import ssm as S


def _batch_for(cfg, B=2, S_=32, seed=1):
    batch = {"tokens": jax.random.randint(jax.random.key(seed), (B, S_),
                                          0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["patches"] = jnp.ones((B, 8, cfg.frontend_dim), jnp.float32) * 0.1
    if cfg.family == "audio":
        batch["frames"] = jnp.ones((B, cfg.encoder_seq, cfg.frontend_dim),
                                   jnp.float32) * 0.1
    return batch


# ---------------------------------------------------------------------------
# per-arch smoke: one forward + one train step, shapes + no NaNs
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_arch_smoke_forward_and_train_step(arch):
    from repro.launch.steps import init_train_state, make_train_step
    from repro.optim import adamw

    cfg = get_arch(arch).reduced()
    assert cfg.n_layers == 2 and cfg.d_model <= 512
    if cfg.n_experts:
        assert cfg.n_experts <= 4
    model = build_model(cfg)
    opt = adamw(1e-3)
    state = init_train_state(model, opt, jax.random.key(0))
    batch = _batch_for(cfg)
    loss, _ = model.loss(state["params"], batch)
    assert loss.shape == () and bool(jnp.isfinite(loss))
    new_state, metrics = jax.jit(make_train_step(model, opt))(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    # params actually changed and stayed finite
    moved = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                         state["params"], new_state["params"])
    assert max(jax.tree.leaves(moved)) > 0
    assert all(bool(jnp.all(jnp.isfinite(x)))
               for x in jax.tree.leaves(new_state["params"]))


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_arch_smoke_decode_step(arch):
    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    if model.cfg.family == "ds2":
        pytest.skip("ds2 is non-autoregressive")
    params = model.init(jax.random.key(0))
    B = 2
    cache = model.init_cache(B, 16)
    logits, new_cache = model.decode(
        params, cache,
        {"tokens": jnp.ones((B, 1), jnp.int32),
         "pos": jnp.zeros((B,), jnp.int32)})
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


# ---------------------------------------------------------------------------
# numeric equivalences
# ---------------------------------------------------------------------------


def _naive_attention(q, k, v, causal=True, window=0):
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    qh = q.reshape(B, Sq, KV, G, D).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bckd->bkgqc", qh, k.astype(jnp.float32)) * D ** -0.5
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= qpos - kpos < window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqc,bckd->bkgqd", p, v.astype(jnp.float32))
    return o.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, D)


@pytest.mark.parametrize("causal,window,Sq", [
    (True, 0, 64), (True, 0, 100), (False, 0, 64), (True, 16, 64)])
def test_chunked_attention_matches_naive(causal, window, Sq):
    key = jax.random.key(0)
    B, H, KV, D = 2, 4, 2, 16
    q = jax.random.normal(key, (B, Sq, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, Sq, KV, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, Sq, KV, D))
    got = L.chunked_attention(q, k, v, causal=causal, window=window,
                              q_chunk=32, k_chunk=32)
    want = _naive_attention(q, k, v, causal, window)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_chunked_attention_nondiff_path_matches():
    key = jax.random.key(3)
    B, S_, H, KV, D = 1, 96, 4, 4, 8
    q = jax.random.normal(key, (B, S_, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S_, KV, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S_, KV, D))
    a = L.chunked_attention(q, k, v, q_chunk=32, k_chunk=32,
                            differentiable=True)
    b = L.chunked_attention(q, k, v, q_chunk=32, k_chunk=32,
                            differentiable=False)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def _mamba1_sequential(dt, A, Bm, Cm, x):
    B_, T, d = x.shape
    N = A.shape[1]
    h = jnp.zeros((B_, d, N))
    ys = []
    for t in range(T):
        a = jnp.exp(dt[:, t, :, None] * A)
        h = a * h + (dt[:, t] * x[:, t])[..., None] * Bm[:, t, None, :]
        ys.append(jnp.einsum("bdn,bn->bd", h, Cm[:, t]))
    return jnp.stack(ys, 1), h


def test_mamba1_chunked_scan_matches_sequential():
    rng = np.random.RandomState(0)
    B_, T, d, N = 2, 37, 8, 4
    dt = jnp.asarray(np.abs(rng.randn(B_, T, d)) * 0.1, jnp.float32)
    A = -jnp.asarray(np.abs(rng.randn(d, N)) + 0.1, jnp.float32)
    Bm = jnp.asarray(rng.randn(B_, T, N), jnp.float32)
    Cm = jnp.asarray(rng.randn(B_, T, N), jnp.float32)
    x = jnp.asarray(rng.randn(B_, T, d), jnp.float32)
    h0 = jnp.zeros((B_, d, N))
    got_y, got_h = S._mamba1_chunked_scan(dt, A, Bm, Cm, x, h0, chunk=8)
    want_y, want_h = _mamba1_sequential(dt, A, Bm, Cm, x)
    np.testing.assert_allclose(got_y, want_y, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(got_h, want_h, rtol=1e-4, atol=1e-4)


def _ssd_sequential(x, dt, A, Bm, Cm, h0):
    B_, T, H, Pd = x.shape
    h = h0
    ys = []
    for t in range(T):
        decay = jnp.exp(dt[:, t] * A)  # (B,H)
        upd = jnp.einsum("bhp,bn->bhpn", x[:, t] * dt[:, t][..., None],
                         Bm[:, t])
        h = decay[:, :, None, None] * h + upd
        ys.append(jnp.einsum("bhpn,bn->bhp", h, Cm[:, t]))
    return jnp.stack(ys, 1), h


def test_ssd_chunked_matches_sequential():
    rng = np.random.RandomState(1)
    B_, T, H, Pd, N = 2, 29, 3, 4, 5
    x = jnp.asarray(rng.randn(B_, T, H, Pd), jnp.float32)
    dt = jnp.asarray(np.abs(rng.randn(B_, T, H)) * 0.2, jnp.float32)
    A = -jnp.asarray(np.abs(rng.randn(H)) + 0.2, jnp.float32)
    Bm = jnp.asarray(rng.randn(B_, T, N), jnp.float32)
    Cm = jnp.asarray(rng.randn(B_, T, N), jnp.float32)
    h0 = jnp.zeros((B_, H, Pd, N))
    got_y, got_h = S._ssd_scan(x, dt, A, Bm, Cm, h0, chunk=8)
    want_y, want_h = _ssd_sequential(x, dt, A, Bm, Cm, h0)
    np.testing.assert_allclose(got_y, want_y, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(got_h, want_h, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# prefill + decode == full forward (next-token logits)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["stablelm-1.6b", "falcon-mamba-7b",
                                  "zamba2-2.7b", "kimi-k2-1t-a32b"])
def test_prefill_decode_consistency(arch):
    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    B, S_ = 2, 24
    tokens = jax.random.randint(jax.random.key(5), (B, S_ + 1), 0,
                                cfg.vocab_size)
    # reference: full forward over S_+1 tokens -> logits at position S_
    if cfg.family == "hybrid":
        from repro.models.hybrid import _forward
        x, _ = _forward(params, tokens, cfg, collect_state=False)
        want = (x[:, -1] @ params["lm_head"]).astype(jnp.float32)
    else:
        from repro.models.transformer import lm_logits_and_aux
        x, head, _ = lm_logits_and_aux(params, {"tokens": tokens}, cfg)
        want = (x[:, -1] @ head).astype(jnp.float32)
    # prefill on S_ tokens, then decode token S_
    _, cache = model.prefill(params, {"tokens": tokens[:, :S_]})
    cache = model.grow_cache(cache, S_ + 1)
    got, _ = model.decode(params, cache,
                          {"tokens": tokens[:, S_:],
                           "pos": jnp.full((B,), S_, jnp.int32)})
    np.testing.assert_allclose(
        jax.nn.log_softmax(got), jax.nn.log_softmax(want),
        rtol=2e-3, atol=2e-3)


def test_sliding_window_decode_receptive_field():
    """With window W and L layers the decode receptive field is L*(W-1):
    tokens outside it must not affect the logits; tokens inside must."""
    cfg = get_arch("stablelm-1.6b").reduced()  # L = 2
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    B, W, T = 1, 8, 20
    toks = jax.random.randint(jax.random.key(7), (B, T), 0, cfg.vocab_size)

    def run(tk):
        cache = model.init_cache(B, W)
        logits = None
        for t in range(T):
            logits, cache = model.decode(
                params, cache, {"tokens": tk[:, t : t + 1],
                                "pos": jnp.full((B,), t, jnp.int32)},
                window=W)
        return logits

    base = run(toks)
    # positions 0..3 are beyond 2*(W-1)=14 steps back from pos 19 -> no effect
    far = run(toks.at[:, :4].set((toks[:, :4] + 3) % cfg.vocab_size))
    np.testing.assert_allclose(base, far, rtol=1e-5, atol=1e-5)
    # a token inside the window must change the logits
    near = run(toks.at[:, 18].set((toks[:, 18] + 3) % cfg.vocab_size))
    assert float(jnp.abs(base - near).max()) > 1e-3


def test_whisper_prefill_decode_consistency():
    """enc-dec: prefill(S) + decode(S+1th) == full decoder forward."""
    cfg = get_arch("whisper-tiny").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    B, S_ = 2, 12
    frames = jnp.ones((B, cfg.encoder_seq, cfg.frontend_dim)) * 0.1
    tokens = jax.random.randint(jax.random.key(3), (B, S_ + 1), 0,
                                cfg.vocab_size)
    from repro.models.whisper import decoder_forward, encode

    enc = encode(params, frames, cfg)
    x, _ = decoder_forward(params, tokens, enc, cfg)
    want = (x[:, -1] @ params["lm_head"]).astype(jnp.float32)

    _, cache = model.prefill(params, {"frames": frames,
                                      "tokens": tokens[:, :S_]})
    cache = model.grow_cache(cache, S_ + 1)
    got, _ = model.decode(params, cache,
                          {"tokens": tokens[:, S_:],
                           "pos": jnp.full((B,), S_, jnp.int32)})
    np.testing.assert_allclose(jax.nn.log_softmax(got),
                               jax.nn.log_softmax(want), rtol=2e-3, atol=2e-3)


def test_vlm_prefill_runs_with_patches():
    """VLM: prefill consumes the stub patch prefix; decode continues."""
    cfg = get_arch("qwen2-vl-2b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    B, S_, NP = 2, 10, 4
    batch = {"tokens": jax.random.randint(jax.random.key(4), (B, S_), 0,
                                          cfg.vocab_size),
             "patches": jnp.ones((B, NP, cfg.frontend_dim)) * 0.1}
    logits, cache = model.prefill(params, batch)
    assert logits.shape == (B, cfg.vocab_size)
    # cache covers patches + tokens
    assert cache["k"].shape[2] == S_ + NP
    cache = model.grow_cache(cache, S_ + NP + 1)
    l2, _ = model.decode(params, cache,
                         {"tokens": jnp.ones((B, 1), jnp.int32),
                          "pos": jnp.full((B,), S_ + NP, jnp.int32)})
    assert bool(jnp.all(jnp.isfinite(l2)))


# ---------------------------------------------------------------------------
# CTC
# ---------------------------------------------------------------------------


def _brute_force_ctc(log_probs, labels):
    """Enumerate all alignments (tiny T, L only)."""
    import itertools

    T, V = log_probs.shape

    def collapse(path):
        out = []
        prev = -1
        for p in path:
            if p != 0 and p != prev:
                out.append(p)
            prev = p
        return out

    total = -np.inf
    for path in itertools.product(range(V), repeat=T):
        if collapse(path) == list(labels):
            lp = sum(log_probs[t, p] for t, p in enumerate(path))
            total = np.logaddexp(total, lp)
    return total


def test_ctc_matches_brute_force():
    from repro.models.deepspeech2 import ctc_loss

    rng = np.random.RandomState(0)
    T, V, L = 5, 4, 2
    logits = rng.randn(1, T, V).astype(np.float32)
    lp = jax.nn.log_softmax(jnp.asarray(logits), -1)
    labels = jnp.asarray([[1, 2]], jnp.int32)
    got = ctc_loss(lp, labels, jnp.asarray([T]), jnp.asarray([L]))
    want = -_brute_force_ctc(np.asarray(lp[0]), [1, 2]) / L
    np.testing.assert_allclose(float(got), float(want), rtol=1e-4)


def test_mrope_sections_rotate_by_stream():
    """M-RoPE: with distinct position streams, different sections rotate
    differently; with identical streams it reduces to standard RoPE."""
    B, S_, H, D = 1, 6, 2, 16
    x = jax.random.normal(jax.random.key(0), (B, S_, H, D))
    pos = jnp.arange(S_, dtype=jnp.int32)[None]
    pos3 = jnp.broadcast_to(pos[:, None], (B, 3, S_))
    a = L.apply_mrope(x, pos3, 100.0, (2, 3, 3))
    b = L.apply_rope(x, pos, 100.0)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_flash_kernel_prefill_matches_jnp_path():
    """cfg.use_flash_kernel routes prefill attention through the Pallas
    kernel (interpret mode on CPU) — logits must match the jnp path."""
    cfg = get_arch("stablelm-1.6b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = {"tokens": jax.random.randint(jax.random.key(9), (2, 24), 0,
                                          cfg.vocab_size)}
    want, _ = model.prefill(params, batch)

    cfg_fl = cfg.with_(use_flash_kernel=True)
    model_fl = build_model(cfg_fl)
    got, _ = model_fl.prefill(params, batch)
    np.testing.assert_allclose(jax.nn.log_softmax(got),
                               jax.nn.log_softmax(want),
                               rtol=2e-3, atol=2e-3)
