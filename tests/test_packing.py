"""pack/unpack round-trips over the static flat Layout (core/packing.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import packing


def _mixed_tree():
    rng = np.random.RandomState(0)
    return {
        "w": jnp.asarray(rng.randn(17, 9).astype(np.float32)),
        "b": jnp.asarray(rng.randn(33).astype(np.float32) * 5, jnp.bfloat16),
        "nested": [
            jnp.asarray(rng.randint(-50, 50, size=(4, 3)), jnp.int32),
            (jnp.asarray(2.5, jnp.float32), jnp.asarray(rng.randn(7, 1, 2),
                                                        jnp.float32)),
        ],
    }


def test_default_block_matches_fused_kernel_tile():
    # layout-padded rows must drop into the fused OTA kernel unre-padded
    from repro.kernels.ota_fused import BLOCK_COLS

    assert packing.DEFAULT_BLOCK == BLOCK_COLS


def test_layout_static_fields():
    tree = _mixed_tree()
    lay = packing.make_layout(tree, block=128)
    assert lay.size == 17 * 9 + 33 + 12 + 1 + 14
    assert lay.padded_size % 128 == 0
    assert lay.padded_size >= lay.size
    assert lay.offsets[0] == 0
    assert lay.offsets[-1] + lay.sizes[-1] == lay.size
    # hashable => usable as a jit static argument
    assert hash(lay) == hash(packing.make_layout(tree, block=128))


def test_pack_unpack_roundtrip_mixed_dtypes():
    tree = _mixed_tree()
    lay = packing.make_layout(tree, block=256)
    flat = packing.pack(tree, lay)
    assert flat.shape == (lay.padded_size,) and flat.dtype == jnp.float32
    # pad region is exact zeros
    assert float(jnp.abs(flat[lay.size :]).max()) == 0.0
    got = packing.unpack(flat, lay)
    assert jax.tree.structure(got) == jax.tree.structure(tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=1e-6
        )


def test_unpack_without_cast_keeps_f32():
    tree = _mixed_tree()
    lay = packing.make_layout(tree)
    got = packing.unpack(packing.pack(tree, lay), lay, cast=False)
    assert all(l.dtype == jnp.float32 for l in jax.tree.leaves(got))


def test_pack_batch_stacks_rows():
    rng = np.random.RandomState(1)
    trees = [
        {
            "a": jnp.asarray(rng.randn(50).astype(np.float32)),
            "b": jnp.asarray(rng.randn(6, 6).astype(np.float32)),
        }
        for _ in range(4)
    ]
    lay = packing.make_layout(trees[0], block=64)
    X = packing.pack_batch(trees, lay)
    assert X.shape == (4, lay.padded_size)
    for i, t in enumerate(trees):
        np.testing.assert_array_equal(
            np.asarray(X[i]), np.asarray(packing.pack(t, lay))
        )


def test_scalar_and_empty_padding_edges():
    tree = {"s": jnp.asarray(3.0)}
    lay = packing.make_layout(tree, block=8)
    assert lay.size == 1 and lay.padded_size == 8
    got = packing.unpack(packing.pack(tree, lay), lay)
    assert float(got["s"]) == 3.0 and got["s"].shape == ()


def test_layout_mismatch_is_detected():
    tree = _mixed_tree()
    lay = packing.make_layout(tree, block=128)
    with pytest.raises(AssertionError):
        packing.pack({"only": jnp.zeros((3,))}, lay)
