"""Continuous-batching serve engine: correctness + scheduling behaviour."""
import numpy as np
import pytest

from repro.configs import get_arch
from repro.serve import Request, ServeEngine


@pytest.fixture(scope="module")
def engine():
    cfg = get_arch("stablelm-1.6b").reduced()
    return ServeEngine(cfg, max_batch=4, cache_len=96)


def _requests(n, seed=0, vocab=512):
    rng = np.random.RandomState(seed)
    return [Request(i, rng.randint(0, vocab, size=rng.randint(4, 12))
                    .astype(np.int32), max_new_tokens=int(rng.randint(4, 16)))
            for i in range(n)]


def test_drains_all_requests(engine):
    for r in _requests(9, seed=1):
        engine.submit(r)
    done = engine.run_until_drained()
    assert len(done) >= 9
    for r in done:
        assert r.state == "DONE"
        assert 1 <= len(r.generated) <= r.max_new_tokens


def test_continuous_batching_interleaves():
    cfg = get_arch("stablelm-1.6b").reduced()
    eng = ServeEngine(cfg, max_batch=4, cache_len=96)
    for r in _requests(8, seed=2):
        eng.submit(r)
    eng.run_until_drained()
    s = eng.stats()
    # with 8 requests on 4 slots, slots must be refilled mid-run:
    # average active batch strictly above 1
    assert s["tokens_per_step"] > 1.0
    assert s["completed"] == 8


def test_slot_isolation_cache_state():
    """A request's cache state after prefill must not depend on its
    co-batched neighbours.

    Compared at the KV-cache level (the prompt's K/V entries), which is
    pre-argmax: greedy token sequences are brittle to run-to-run argmax
    flips on the near-tied logits of an untrained model, but the slot's
    prefill cache rows are a pure function of the prompt.
    """
    cfg = get_arch("stablelm-1.6b").reduced()
    prompt = np.arange(1, 9, dtype=np.int32)

    def prefill_cache(extra_traffic: bool):
        eng = ServeEngine(cfg, max_batch=4, cache_len=96)
        if extra_traffic:
            for r in _requests(3, seed=3):
                r.request_id += 100
                eng.submit(r)
            eng.step()
        eng.submit(Request(0, prompt, max_new_tokens=8))
        eng.step()  # admits request 0 into a free slot (prefill)
        req0 = next(r for r in (eng.slots + eng.completed)
                    if r and r.request_id == 0)
        s = req0.slot
        P = len(prompt)
        return {name: np.asarray(eng.cache[name][:, s, :P])
                for name in ("k", "v", "pos")}

    solo = prefill_cache(False)
    busy = prefill_cache(True)
    for name in ("k", "v"):
        np.testing.assert_allclose(solo[name], busy[name],
                                   rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(solo["pos"], busy["pos"])


def test_ssm_engine_decodes():
    cfg = get_arch("falcon-mamba-7b").reduced()
    eng = ServeEngine(cfg, max_batch=2, cache_len=64)
    for r in _requests(3, seed=4):
        eng.submit(r)
    done = eng.run_until_drained()
    assert len(done) == 3
