"""Retrieval subsystem (DESIGN.md §10): arena growth, the int8 blockwise
storage class, batched top-k == brute force exactly on f32 stores, the
Pallas kernel == jnp oracle bitwise on ragged record counts, tie/edge
semantics, ckpt round-trips, and the cohort-batched planner parity."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.profiling import RAGPlanner, make_fleet, make_users, plan_round
from repro.core.profiling.ragdb import (
    ContextQuantFeedbackDB,
    HardwareQuantPerfDB,
    VectorStore,
    embed_batch,
    embed_features,
)
from repro.core.profiling.users import satisfaction_score, true_performance
from repro.kernels.ops import topk_cosine
from repro.kernels.topk_similarity import TILE_N, TOPK_LANES
from repro.retrieval import (
    ArenaStore,
    RetrievalEngine,
    brute_force_topk,
    normalize_rows,
    stable_topk,
)


def _unit_rows(n, d=256, seed=0):
    rng = np.random.RandomState(seed)
    return normalize_rows(rng.randn(n, d).astype(np.float32))


# ---------------------------------------------------------------------------
# arena storage
# ---------------------------------------------------------------------------


def test_arena_growth_preserves_vectors():
    vecs = _unit_rows(3000, d=64, seed=1)
    st = ArenaStore(64)
    st.add_batch(vecs[:100])
    for v in vecs[100:200]:
        st.add(v)
    st.add_batch(vecs[200:])
    assert len(st) == 3000
    assert st.capacity % TILE_N == 0 and st.capacity >= 3000
    np.testing.assert_array_equal(st.vectors(), vecs)
    # capacity padding stays exact zeros (the kernel feeds on the raw slab)
    data, _ = st.raw()
    assert not np.any(data[3000:])


def test_arena_int8_blockwise_roundtrip_error_bounded():
    vecs = _unit_rows(300, d=256, seed=2)
    st = ArenaStore(256, storage="int8", qblock=64)
    st.add_batch(vecs)
    deq = st.vectors()
    # RTN on the symmetric amax/127 grid: error <= scale/2 per element
    amax = np.abs(vecs.reshape(300, 4, 64)).max(axis=2)
    bound = np.repeat(np.maximum(amax, 1e-12) / 127.0, 64, axis=1) / 2
    assert np.all(np.abs(deq - vecs) <= bound + 1e-7)
    assert st.nbytes < 0.3 * vecs.nbytes


# ---------------------------------------------------------------------------
# batched top-k == brute force, kernel == oracle
# ---------------------------------------------------------------------------


def test_engine_equals_brute_force_exactly_f32():
    vecs = _unit_rows(1500, seed=3)
    st = ArenaStore(256)
    st.add_batch(vecs)
    queries = _unit_rows(9, seed=4)
    s_eng, i_eng = RetrievalEngine(st, use_kernel=False).topk(queries, 20)
    s_bf, i_bf = brute_force_topk(st.vectors(), queries, 20)
    np.testing.assert_array_equal(i_eng, i_bf)
    np.testing.assert_array_equal(s_eng, s_bf)  # scores too, bit-for-bit


@pytest.mark.parametrize("storage", ["f32", "int8"])
def test_kernel_bit_equal_to_oracle_ragged_n(storage):
    """N = 777 is not a multiple of the 256-record tile: the capacity
    slab is padded and the live-count mask must hide the tail."""
    vecs = _unit_rows(777, seed=5)
    st = ArenaStore(256, storage=storage)
    st.add_batch(vecs)
    queries = jnp.asarray(_unit_rows(5, seed=6))
    data, scales = st.raw()
    data = jnp.asarray(data)
    scales = None if scales is None else jnp.asarray(scales)
    n = jnp.int32(len(st))
    s_k, i_k = topk_cosine(queries, data, scales, n, k=33, use_kernel=True)
    s_o, i_o = topk_cosine(queries, data, scales, n, k=33, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(s_k), np.asarray(s_o))
    np.testing.assert_array_equal(np.asarray(i_k), np.asarray(i_o))
    # and the kernel's selection matches the numpy engine's
    s_np, i_np = RetrievalEngine(st, use_kernel=False).topk(np.asarray(queries), 33)
    np.testing.assert_array_equal(np.asarray(i_k), i_np)
    np.testing.assert_allclose(np.asarray(s_k), s_np, rtol=1e-5, atol=1e-6)


def test_kernel_path_through_engine_matches_numpy_path():
    vecs = _unit_rows(600, seed=7)
    st = ArenaStore(256)
    st.add_batch(vecs)
    queries = _unit_rows(3, seed=8)
    s_k, i_k = RetrievalEngine(st, use_kernel=True).topk(queries, 10)
    s_n, i_n = RetrievalEngine(st, use_kernel=False).topk(queries, 10)
    np.testing.assert_array_equal(i_k, i_n)
    np.testing.assert_allclose(s_k, s_n, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# tie and edge semantics
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("use_kernel", [False, True])
def test_tied_scores_resolve_to_lowest_indices(use_kernel):
    """Duplicate records score identically; the contract returns them in
    ascending record-index order — in every engine path."""
    v = _unit_rows(2, seed=9)
    st = ArenaStore(256)
    st.add_batch(np.stack([v[0]] * 10 + [v[1]] * 3))
    scores, idx = RetrievalEngine(st, use_kernel=use_kernel).topk(v[:1], 12)
    np.testing.assert_array_equal(idx[0], np.arange(12))
    assert np.all(scores[0, :10] == scores[0, 0])


def test_empty_store_and_k_greater_than_n():
    st = ArenaStore(256)
    queries = _unit_rows(4, seed=10)
    scores, idx = RetrievalEngine(st, use_kernel=False).topk(queries, 8)
    assert scores.shape == (4, 0) and idx.shape == (4, 0)
    st.add_batch(_unit_rows(5, seed=11))
    scores, idx = RetrievalEngine(st, use_kernel=False).topk(queries, 50)
    assert scores.shape == (4, 5)  # k clamps to n
    s_bf, i_bf = brute_force_topk(st.vectors(), queries, 50)
    np.testing.assert_array_equal(idx, i_bf)


def test_stable_topk_full_width_matches_argsort():
    rng = np.random.RandomState(12)
    scores = rng.randn(3, 40).astype(np.float32)
    scores[:, 7] = scores[:, 21]  # plant exact ties
    s_a, i_a = stable_topk(scores, 40)
    order = np.argsort(-scores, axis=1, kind="stable")
    np.testing.assert_array_equal(i_a, order)
    s_b, i_b = stable_topk(scores, 11)
    np.testing.assert_array_equal(i_b, order[:, :11])
    np.testing.assert_array_equal(s_b, s_a[:, :11])


def test_zero_norm_query_guard():
    legacy = VectorStore()
    db = ContextQuantFeedbackDB()
    for store in (legacy, db):
        store.add({"loc_bedroom": 1.0}, {"bits": 8, "satisfaction": 0.5, "perf": {}})
    assert legacy.query({}) == []
    assert db.query({}) == []
    assert db.estimate_satisfaction({}, 8) is None
    # zero rows inside a batch: sim-0 hits, filtered by the estimators
    hits = db.query_batch(np.zeros((1, 256), np.float32), 4)
    assert all(s == 0.0 for s, _ in hits[0])


# ---------------------------------------------------------------------------
# int8 retrieval quality
# ---------------------------------------------------------------------------


def test_int8_recall_close_to_f32():
    vecs = _unit_rows(2000, seed=13)
    st32 = ArenaStore(256)
    st8 = ArenaStore(256, storage="int8")
    st32.add_batch(vecs)
    st8.add_batch(vecs)
    queries = normalize_rows(vecs[:32] + 0.05 * _unit_rows(32, seed=14))
    _, i32 = RetrievalEngine(st32, use_kernel=False).topk(queries, 10)
    _, i8 = RetrievalEngine(st8, use_kernel=False).topk(queries, 10)
    overlap = np.mean([len(set(a) & set(b)) / 10 for a, b in zip(i32, i8)])
    assert overlap >= 0.8, overlap
    assert st8.nbytes <= 0.3 * st32.nbytes


# ---------------------------------------------------------------------------
# arena DBs vs the legacy oracle, persistence
# ---------------------------------------------------------------------------


def test_arena_db_matches_legacy_oracle():
    rng = np.random.RandomState(15)
    legacy = VectorStore()
    db = HardwareQuantPerfDB()
    feats = []
    for i in range(200):
        f = {f"k{rng.randint(6)}": float(rng.uniform(0.1, 2.0))}
        feats.append(f)
        payload = {"bits": int(rng.choice([4, 8, 16])), "perf": {"x": float(i)}}
        legacy.add(f, payload)
        db.add(f, payload)
    for f in feats[:20]:
        a = legacy.query(f, k=9)
        b = db.query(f, k=9)
        xa = [rec.payload["perf"]["x"] for _, rec in a]
        xb = [rec.payload["perf"]["x"] for _, rec in b]
        assert xa == xb
        np.testing.assert_allclose(
            [s for s, _ in a], [s for s, _ in b], rtol=1e-5, atol=1e-6
        )


def _make_db(storage):
    db = ContextQuantFeedbackDB()
    if storage != "f32":
        db.arena = ArenaStore(256, storage=storage)
        db.engine = RetrievalEngine(db.arena, use_kernel=False)
    return db


@pytest.mark.parametrize("storage", ["f32", "int8"])
def test_store_save_restore_and_append_only_writeback(tmp_path, storage):
    db = _make_db(storage)
    for i in range(40):
        db.add_feedback({"loc_bedroom": 1.0, f"u{i}": 0.3}, 8, i / 40.0, {})
    path = str(tmp_path / f"cqf_{storage}.ckpt")
    db.save(path)
    fresh = _make_db(storage)
    fresh.restore(path)
    assert len(fresh) == len(db) == 40
    q = {"loc_bedroom": 1.0}
    got = [(s, rec.payload["satisfaction"]) for s, rec in fresh.query(q, 6)]
    want = [(s, rec.payload["satisfaction"]) for s, rec in db.query(q, 6)]
    assert got == want
    # feedback writeback after restore is append-only and queryable
    fresh.add_feedback({"loc_kitchen": 1.0}, 4, 0.9, {})
    assert len(fresh) == 41
    top = fresh.query({"loc_kitchen": 1.0}, 1)
    assert top[0][1].payload["bits"] == 4


# ---------------------------------------------------------------------------
# cohort-batched planning
# ---------------------------------------------------------------------------


def test_plan_cohort_matches_per_client_plan():
    users = make_users(20, seed=21)
    fleet = make_fleet(20, seed=21)
    a = RAGPlanner(seed=21)
    b = RAGPlanner(seed=21)
    for _ in range(3):
        da = plan_round(a.plan(users, fleet))
        db = plan_round(b.plan_cohort(users, fleet))
        assert [d.bits for d in da] == [d.bits for d in db]
        for d, u, s in zip(da, users, fleet):
            sat = satisfaction_score(u, s, d.bits)
            perf = true_performance(u, s, d.bits)
            a.observe_feedback(u, s, d.bits, sat, perf)
            b.observe_feedback(u, s, d.bits, sat, perf)
    assert len(a.cqf_db) == len(b.cqf_db) > 0


def test_plan_cohort_empty_cohort_and_subclass_override():
    from repro.core.profiling.planner import PlanDecision

    assert RAGPlanner(seed=0).plan_cohort([], []) == []

    class FloorBitsPlanner(RAGPlanner):
        def plan(self, users, specs, **kw):
            return [
                PlanDecision(u.user_id, min(s.supported_bits), 0.0, [])
                for u, s in zip(users, specs)
            ]

    users = make_users(5, seed=30)
    fleet = make_fleet(5, seed=30)
    # a customized per-client pipeline must not be bypassed by the
    # batched entry point the FL server calls
    got = FloorBitsPlanner(seed=30).plan_cohort(users, fleet)
    assert [d.bits for d in got] == [min(s.supported_bits) for s in fleet]


def test_query_batch_equals_serial_queries():
    db = ContextQuantFeedbackDB()
    rng = np.random.RandomState(22)
    for i in range(120):
        db.add_feedback(
            {f"f{rng.randint(8)}": float(rng.uniform(0.2, 1.5))},
            int(rng.choice([4, 8, 16])),
            float(rng.uniform()),
            {},
        )
    feats = [{f"f{i % 8}": 1.0} for i in range(10)]
    batched = db.query_batch(embed_batch(feats), k=12)
    for f, hits in zip(feats, batched):
        serial = db.query(f, k=12)
        assert [id(rec) for _, rec in serial] == [id(rec) for _, rec in hits]


def test_embed_batch_matches_embed_features():
    feats = [{"a": 1.0}, {"b": 0.5, "c": 0.2}, {}]
    mat = embed_batch(feats)
    assert mat.shape == (3, 256)
    for row, f in zip(mat, feats):
        np.testing.assert_array_equal(row, embed_features(f))


def test_topk_lanes_bound_enforced():
    st = ArenaStore(256)
    st.add_batch(_unit_rows(300, seed=23))
    queries = _unit_rows(2, seed=24)
    # k beyond the kernel's running top-k width falls back to numpy
    scores, idx = RetrievalEngine(st, use_kernel=True).topk(queries, TOPK_LANES + 50)
    assert scores.shape == (2, TOPK_LANES + 50)
    s_bf, i_bf = brute_force_topk(st.vectors(), queries, TOPK_LANES + 50)
    np.testing.assert_array_equal(idx, i_bf)
