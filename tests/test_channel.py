"""Physical OTA channel model (DESIGN.md §12): property tests pinning the
channel math bit-for-bit.

Four contracts, each asserted with exact (``==``) float equality:

- kernel == oracle: the gain-aware Pallas pass (``ota_packed_2d`` /
  ``ota_fold_2d`` with ``gains=``) matches the jnp oracles bitwise for
  every storage class, including truncated (zero-gain) rows;
- ``gains=None`` regression: the unit channel is bitwise identical to
  the pre-channel aggregation, in barrier and streaming modes;
- truncation == exclusion: zero-gain rows contribute exactly nothing —
  the aggregate equals dropping those rows before aggregation;
- stream separation: the channel fading draw, the legacy channel/dither/
  noise splits, and the numpy round streams are pairwise distinct (the
  seed-reuse hazard fix in ``fl/server.round_rng``).

Runs under real hypothesis when installed, else the deterministic
fallback sampler (tests/_hypothesis_fallback.py) — tier-1 needs no
extra wheels.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # bare container: deterministic fallback sampler
    from _hypothesis_fallback import given, settings, st

from repro.core import channel as chan
from repro.core import ota, packing
from repro.fl.server import round_drift_rng, round_rng
from repro.kernels import ota_fused as kf
from repro.kernels import ref as kref

M = 4096
K = 5

STORAGE = [(4, 0), (4, packing.QUANT_BLOCK), (8, 0),
           (8, packing.QUANT_BLOCK), (16, 0), (16, packing.QUANT_BLOCK),
           (32, 0)]


def _rows(bits_list, block=0, seed=0):
    """Packed cohort rows (one flat leaf, quantized at the edge)."""
    rng = np.random.RandomState(seed)
    tree = {"w": jnp.zeros((M,), jnp.float32)}
    layout = packing.make_layout(tree)
    key = jax.random.key(seed + 5)
    sr = ota.derive_sr_seed(key)
    rows = []
    for i, b in enumerate(bits_list):
        up = {"w": jnp.asarray(rng.randn(M).astype(np.float32) * 0.01)}
        rows.append(ota.quantize_uplink(packing.pack(up, layout), b, sr, i,
                                        block=block))
    return rows, layout, key


def _group(rows):
    kinds, datas, scales, _ = ota._group_rows(rows)
    assert len(kinds) == 1
    (kind, qblock), data, scale = kinds[0], datas[0], scales[0]
    return data, scale, qblock, kind == "int4"


def _gains(rng, k, zero_first=True):
    g = rng.rand(k).astype(np.float32)
    if zero_first:
        g[0] = 0.0  # always exercise a truncated row
    return jnp.asarray(g)


# ---------------------------------------------------------------------------
# kernel == oracle with gains (property: random gains, every storage class)
# ---------------------------------------------------------------------------


@settings(deadline=None, max_examples=5)
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from(STORAGE))
def test_gain_superpose_kernel_matches_oracle(seed, storage):
    bits, block = storage
    rows, _, _ = _rows([bits] * K, block=block, seed=seed % 997)
    data, scale, qblock, packed4 = _group(rows)
    rng = np.random.RandomState(seed % 2 ** 31)
    w = jnp.asarray(rng.rand(K).astype(np.float32))
    g = _gains(rng, K)
    got = kf.ota_packed_2d(data, scale, w, gains=g, qblock=qblock,
                           packed4=packed4, interpret=True)
    want = kref.ota_packed_ref(data, scale, w, gains=g, qblock=qblock,
                               packed4=packed4)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(deadline=None, max_examples=5)
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from(STORAGE))
def test_gain_fold_kernel_matches_oracle(seed, storage):
    bits, block = storage
    rows, layout, _ = _rows([bits] * K, block=block, seed=seed % 997)
    data, scale, qblock, packed4 = _group(rows)
    rng = np.random.RandomState(seed % 2 ** 31)
    acc = jnp.asarray(rng.randn(layout.padded_size).astype(np.float32))
    w = jnp.asarray(rng.rand(K).astype(np.float32))
    g = _gains(rng, K)
    got = kf.ota_fold_2d(acc, data, scale, w, gains=g, qblock=qblock,
                         packed4=packed4, interpret=True)
    want = kref.ota_fold_ref(acc, data, scale, w, gains=g, qblock=qblock,
                             packed4=packed4)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_unit_gains_bitwise_identical_superpose():
    """gains=ones must be bit-identical to the legacy gains=None program
    — kernel and oracle — for every storage class."""
    for bits, block in STORAGE:
        rows, _, _ = _rows([bits] * K, block=block)
        data, scale, qblock, packed4 = _group(rows)
        w = jnp.linspace(0.1, 0.3, K, dtype=jnp.float32)
        ones = jnp.ones((K,), jnp.float32)
        for fn, kw in ((kf.ota_packed_2d, dict(interpret=True)),
                       (kref.ota_packed_ref, {})):
            a = fn(data, scale, w, qblock=qblock, packed4=packed4, **kw)
            b = fn(data, scale, w, gains=ones, qblock=qblock,
                   packed4=packed4, **kw)
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_unit_gains_bitwise_identical_fold():
    for bits, block in STORAGE:
        rows, layout, _ = _rows([bits] * K, block=block)
        data, scale, qblock, packed4 = _group(rows)
        rng = np.random.RandomState(3)
        acc = jnp.asarray(rng.randn(layout.padded_size).astype(np.float32))
        w = jnp.linspace(0.1, 0.3, K, dtype=jnp.float32)
        ones = jnp.ones((K,), jnp.float32)
        for fn, kw in ((kf.ota_fold_2d, dict(interpret=True)),
                       (kref.ota_fold_ref, {})):
            a = fn(acc, data, scale, w, qblock=qblock, packed4=packed4, **kw)
            b = fn(acc, data, scale, w, gains=ones, qblock=qblock,
                   packed4=packed4, **kw)
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# gains=None regression oracle: the PR-5 aggregation, composed by hand
# ---------------------------------------------------------------------------


def test_gains_none_matches_pr5_composition():
    """``ota_aggregate_packed`` without gains must equal the manual
    round_channel -> grouped oracle folds -> AWGN epilogue composition —
    the pre-channel data plane, pinned bitwise."""
    rows, layout, key = _rows([4, 8, 8, 16, 32], block=packing.QUANT_BLOCK)
    weights = jnp.asarray([1.0, 2.0, 1.5, 1.0, 0.5], jnp.float32)
    cfg = ota.OTAConfig(snr_db=17.0)
    kinds, datas, scales, perm = ota._group_rows(rows)
    _, _, w = ota.round_channel(key, weights, cfg=cfg)
    acc = ota._fold_groups(None, kinds, datas, scales, w[perm],
                           use_kernel=False)
    y, _ = ota._awgn_epilogue(key, acc, cfg=cfg, n_valid=layout.size)
    want = packing.unpack(y, layout, cast=False)
    got, _ = ota.ota_aggregate_packed(key, rows, [4, 8, 8, 16, 32],
                                      weights, layout, cfg,
                                      use_kernel=False)
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(want["w"]))


def test_accumulator_unit_gains_identical():
    """Streaming mode: folding with unit gains == folding without, bit
    for bit, across mixed storage classes."""
    rows, layout, key = _rows([4, 8, 16, 32, 4])
    w = jnp.asarray([0.2, 0.3, 0.1, 0.25, 0.15], jnp.float32)
    a0 = ota.OtaAccumulator(layout, use_kernel=False)
    a1 = ota.OtaAccumulator(layout, use_kernel=False)
    a0.fold(rows, w)
    a1.fold(rows, w, gains=jnp.ones((K,), jnp.float32))
    np.testing.assert_array_equal(np.asarray(a0.accumulator),
                                  np.asarray(a1.accumulator))


# ---------------------------------------------------------------------------
# truncation == exclusion (zero-gain rows contribute exactly nothing)
# ---------------------------------------------------------------------------


def _truncated_equals_dropped(use_kernel):
    rows, layout, key = _rows([4, 8, 8, 16, 32])
    bits = [4, 8, 8, 16, 32]
    g = jnp.asarray([0.0, 0.8, 0.0, 1.0, 0.5], jnp.float32)
    cfg = ota.OTAConfig(snr_db=20.0)
    full, info = ota.ota_aggregate_packed(key, rows, bits, [1.0] * K,
                                          layout, cfg, gains=g,
                                          use_kernel=use_kernel)
    keep = [i for i in range(K) if float(g[i]) > 0]
    sub, _ = ota.ota_aggregate_packed(
        key, [rows[i] for i in keep], [bits[i] for i in keep],
        [1.0] * len(keep), layout, cfg, gains=g[jnp.asarray(keep)],
        use_kernel=use_kernel)
    np.testing.assert_array_equal(np.asarray(full["w"]), np.asarray(sub["w"]))
    assert info["n_participating"] == 3
    assert info["n_truncated"] == 2
    assert info["participation"] == [False, True, False, True, True]


def test_truncated_rows_equal_dropped_rows_oracle():
    _truncated_equals_dropped(use_kernel=False)


def test_truncated_rows_equal_dropped_rows_kernel():
    _truncated_equals_dropped(use_kernel=True)


def test_single_surviving_client():
    """One non-truncated row: the aggregate is that client's update alone
    (weight renormalises to 1), bit-equal to aggregating just it."""
    rows, layout, key = _rows([8, 4, 16])
    g = jnp.asarray([0.0, 0.7, 0.0], jnp.float32)
    cfg = ota.OTAConfig(snr_db=25.0)
    full, info = ota.ota_aggregate_packed(key, rows, [8, 4, 16],
                                          [3.0, 2.0, 1.0], layout, cfg,
                                          gains=g, use_kernel=False)
    solo, _ = ota.ota_aggregate_packed(key, [rows[1]], [4], [1.0], layout,
                                       cfg, gains=g[1:2], use_kernel=False)
    np.testing.assert_array_equal(np.asarray(full["w"]), np.asarray(solo["w"]))
    assert info["n_participating"] == 1


def test_all_truncated_aggregate_is_zero_update():
    """Every row truncated: weights renormalise to all-zero (the 1e-12
    guard, no NaN) and the aggregate is the pure-zero update."""
    rows, layout, key = _rows([8, 8, 8])
    agg, info = ota.ota_aggregate_packed(
        key, rows, [8, 8, 8], [1.0, 1.0, 1.0], layout,
        ota.OTAConfig(snr_db=20.0), gains=jnp.zeros((3,), jnp.float32),
        use_kernel=False)
    arr = np.asarray(agg["w"])
    assert np.all(np.isfinite(arr))
    np.testing.assert_array_equal(arr, np.zeros_like(arr))
    assert info["n_participating"] == 0
    assert info["n_truncated"] == 3


def test_all_truncated_wave_leaves_accumulator_bit_unchanged():
    """Streaming fold of a wave whose rows are all truncated adds exact
    zeros: the accumulator value is bitwise what it was."""
    rows, layout, _ = _rows([4, 8, 16, 32, 8])
    acc = ota.OtaAccumulator(layout, use_kernel=False)
    acc.fold(rows[:2], [0.6, 0.4], gains=jnp.asarray([1.0, 0.5]))
    before = np.asarray(acc.accumulator).copy()
    acc.fold(rows[2:], [0.3, 0.3, 0.4], gains=jnp.zeros((3,), jnp.float32))
    np.testing.assert_array_equal(before, np.asarray(acc.accumulator))
    assert acc.n_folded == 5  # the wave still counts as folded traffic


# ---------------------------------------------------------------------------
# ChannelModel: truncation rule, power budget, misalignment
# ---------------------------------------------------------------------------


def test_channel_model_deterministic():
    cm = chan.ChannelModel()
    key = jax.random.key(9)
    s1, s2 = cm.sample(key, 32), cm.sample(key, 32)
    np.testing.assert_array_equal(np.asarray(s1.habs), np.asarray(s2.habs))
    np.testing.assert_array_equal(np.asarray(s1.gains), np.asarray(s2.gains))


@settings(deadline=None, max_examples=8)
@given(st.integers(0, 2 ** 31 - 1), st.floats(0.01, 1.0),
       st.floats(0.5, 100.0))
def test_truncation_rule_and_gain_range(seed, threshold, budget):
    cfg = chan.ChannelConfig(fade_threshold=threshold, power_budget=budget)
    st_ = chan.ChannelModel(cfg).sample(jax.random.key(seed % 2 ** 31), 48)
    h = np.asarray(st_.habs)
    g = np.asarray(st_.gains)
    tx = np.asarray(st_.tx_amp)
    # truncate exactly when |h|^2 < threshold; gains in [0, 1]
    np.testing.assert_array_equal(g == 0.0, h ** 2 < threshold)
    assert np.all((g >= 0.0) & (g <= 1.0))
    # power budget respected with a float32 ulp of slack
    assert np.all(tx ** 2 <= budget * (1 + 1e-6))


def test_perfect_inversion_when_budget_unconstrained():
    """With a huge power budget every surviving client fully inverts:
    gain exactly 1.0 (h * (rho/h) / rho), no misalignment."""
    habs = jnp.asarray([0.4, 1.0, 2.5], jnp.float32)
    st_ = chan.state_from_habs(
        habs, cfg=chan.ChannelConfig(fade_threshold=0.01,
                                     power_budget=1e9))
    np.testing.assert_array_equal(np.asarray(st_.gains), np.ones(3))
    np.testing.assert_array_equal(np.asarray(st_.misalignment), np.zeros(3))


def test_threshold_boundary_client_participates():
    """|h|^2 exactly at the truncation threshold participates (>=)."""
    cfg = chan.ChannelConfig(fade_threshold=0.25, power_budget=100.0)
    st_ = chan.state_from_habs(jnp.asarray([0.5, 0.49999]), cfg=cfg)
    g = np.asarray(st_.gains)
    assert g[0] > 0.0  # 0.5^2 == 0.25: exactly at threshold, survives
    assert g[1] == 0.0  # just below: truncated


def test_power_budget_exactly_at_inversion_threshold():
    """A client whose full inversion needs exactly the budget amplitude
    (rho/|h| == sqrt(P)) transmits at the cap and aligns perfectly:
    gain exactly 1.0 — the cap binds but does not yet misalign."""
    budget = 16.0  # sqrt(P) = 4
    habs = jnp.asarray([0.25, 0.125], jnp.float32)  # rho/h = 4 and 8
    cfg = chan.ChannelConfig(fade_threshold=1e-4, rho=1.0,
                             power_budget=budget)
    st_ = chan.state_from_habs(habs, cfg=cfg)
    g = np.asarray(st_.gains)
    tx = np.asarray(st_.tx_amp)
    assert tx[0] == 4.0 and g[0] == 1.0  # exactly at the cap: aligned
    assert tx[1] == 4.0 and 0.0 < g[1] < 1.0  # beyond it: misaligned
    assert np.asarray(st_.misalignment)[1] > 0.0


def test_combine_weights_excludes_truncated_and_guards_zero():
    w = chan.combine_weights(jnp.asarray([1.0, 2.0, 3.0]),
                             jnp.asarray([0.0, 0.5, 1.0]))
    w = np.asarray(w)
    assert w[0] == 0.0
    np.testing.assert_allclose(w[1] + w[2], 1.0, rtol=1e-6)
    # all truncated: zeros, not NaN
    w0 = np.asarray(chan.combine_weights(jnp.ones(3), jnp.zeros(3)))
    np.testing.assert_array_equal(w0, np.zeros(3))


# ---------------------------------------------------------------------------
# stream separation (the seed-reuse hazard)
# ---------------------------------------------------------------------------


def test_channel_stream_disjoint_from_legacy_draws():
    """The channel fading key must draw differently from the round key
    itself and from every split(key, 3) child (legacy channel coin-flip,
    SR dither, AWGN) — enabling fading can't shift any legacy stream."""
    key = jax.random.key(123)
    ck = chan.derive_channel_key(key)
    others = list(jax.random.split(key, 3)) + [key]
    a = np.asarray(jax.random.bits(ck, (8,), jnp.uint32))
    for other in others:
        b = np.asarray(jax.random.bits(other, (8,), jnp.uint32))
        assert not np.array_equal(a, b)


def test_round_rng_salts_separate_at_seed_zero():
    """The old ``seed * salt + rnd`` collapsed every salt onto one
    stream at seed=0 (the FLConfig default): dropout and latency draws
    were identical. The mixed streams must now differ pairwise."""
    for rnd in (0, 1, 7):
        drop = round_rng(0, rnd).rand(6)
        lat = round_rng(0, rnd, salt=4099).rand(6)
        bench = round_rng(0, rnd, salt=6151).rand(6)
        assert not np.array_equal(drop, lat)
        assert not np.array_equal(drop, bench)
        assert not np.array_equal(lat, bench)


def test_round_streams_deterministic_and_round_varying():
    a = round_rng(3, 5).rand(4)
    np.testing.assert_array_equal(a, round_rng(3, 5).rand(4))
    assert not np.array_equal(a, round_rng(3, 6).rand(4))
    d = round_drift_rng(0, 2).random()
    assert d == round_drift_rng(0, 2).random()
    assert round_drift_rng(0, 2).random() != round_drift_rng(0, 3).random()


# ---------------------------------------------------------------------------
# FL loop wiring (barrier + streaming under fading)
# ---------------------------------------------------------------------------


def _fl_cfg(**kw):
    from repro.configs.base import FLConfig

    base = dict(n_clients=3, clients_per_round=2, n_rounds=1, local_steps=1,
                local_batch=2, lr=1e-3, planner="unified", seed=0,
                channel_model="fading", fade_threshold=0.3,
                tx_power_budget=4.0)
    base.update(kw)
    return FLConfig(**base)


def test_fading_round_runs_and_records_channel_features():
    from repro.fl import FLServer

    srv = FLServer(_fl_cfg(), shard_size=4)
    log = srv.run_round(0)
    assert math.isfinite(log.train_loss) or log.n_participating == 0
    recorded = [s for s in srv.fleet if s.channel_snr_db is not None]
    assert recorded  # radio state landed on the cohort's DeviceSpecs
    feats = recorded[0].features()
    assert "channel_snr_db" in feats and "truncation_rate" in feats


def test_client_uplink_metadata_echoes_channel_state():
    from repro.fl import FLServer

    srv = FLServer(_fl_cfg(), shard_size=4)
    _, m = srv.clients[0].local_update(
        srv.params, 8, local_steps=1, local_batch=2, lr=1e-3,
        layout=srv.layout, sr_seed=ota.derive_sr_seed(jax.random.key(0)),
        channel_gain=0.8125, channel_habs=1.5)
    assert m["channel_gain"] == 0.8125
    assert m["channel_habs"] == 1.5


def test_all_truncated_round_degenerates_like_all_dropped():
    """An impossible fade threshold truncates the whole cohort: the round
    skips aggregation exactly like the everyone-dropped round (NaN loss,
    params untouched)."""
    from repro.fl import FLServer

    srv = FLServer(_fl_cfg(fade_threshold=1e9), shard_size=4)
    before = np.asarray(jax.tree.leaves(srv.params)[0]).copy()
    log = srv.run_round(0)
    assert log.n_participating == 0
    assert math.isnan(log.train_loss)
    np.testing.assert_array_equal(
        before, np.asarray(jax.tree.leaves(srv.params)[0]))


def test_streaming_equals_barrier_under_fading():
    """No-deadline streaming round under fading == barrier round, bit
    for bit (same channel realisation, same gains in the fused pass)."""
    from repro.fl import FLServer, StreamingFLServer

    s1 = FLServer(_fl_cfg(seed=2), shard_size=4)
    s2 = StreamingFLServer(_fl_cfg(seed=2), shard_size=4)
    s1.run_round(0)
    s2.run_round(0)
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
