"""Synthetic corpus: Table II mixture, shard composition, batching."""
import numpy as np

from repro.core.profiling.users import CATEGORIES, CATEGORY_PROBS, make_users
from repro.data import voice


def test_global_mixture_matches_table_ii():
    """Across many users' shards, the category mixture should approximate
    the paper's Table II distribution (32.7/16.0/31.9/19.4)."""
    users = make_users(150, seed=0)
    counts = {c: 0 for c in CATEGORIES}
    for u in users:
        shard = voice.make_client_shard(u, base_size=16, seed=0)
        for c, n in shard.category_counts().items():
            counts[c] += n
    total = sum(counts.values())
    for c, p in zip(CATEGORIES, CATEGORY_PROBS):
        assert abs(counts[c] / total - p) < 0.06, (c, counts[c] / total, p)


def test_shard_reflects_user_mix():
    users = make_users(40, seed=1)
    # pick a user with a strongly skewed mixture
    u = max(users, key=lambda x: max(x.category_mix.values()))
    shard = voice.make_client_shard(u, base_size=40, seed=1)
    counts = shard.category_counts()
    top_cat = max(u.category_mix, key=u.category_mix.get)
    assert counts[top_cat] == max(counts.values())


def test_frames_noise_scales_with_context():
    ids = voice.encode_text("turn off the lights")
    rng1 = np.random.RandomState(0)
    rng2 = np.random.RandomState(0)
    quiet = voice.synth_frames(ids, 0.1, rng1)
    noisy = voice.synth_frames(ids, 0.9, rng2)
    assert np.abs(noisy - quiet).mean() > 0.1  # noise level actually differs


def test_batchify_shapes_and_lengths():
    users = make_users(3, seed=2)
    shard = voice.make_client_shard(users[0], base_size=6, seed=2)
    b = voice.batchify(shard.utterances, max_frames=320, max_labels=40)
    B = len(shard.utterances)
    assert b["frames"].shape == (B, 320, voice.FEAT_DIM)
    assert b["labels"].shape == (B, 40)
    assert (b["label_len"] > 0).all()
    assert (b["frame_len"] == 8 * b["label_len"]).all()


def test_markov_tokens_learnable_structure():
    from repro.data.lm import MarkovTokens

    src = MarkovTokens(64, seed=0)
    rng = np.random.RandomState(0)
    toks = src.sample(rng, 4, 256)
    assert toks.shape == (4, 256)
    # bigram entropy should be far below uniform (structure exists)
    pairs = {}
    for row in toks:
        for a, b in zip(row[:-1], row[1:]):
            pairs.setdefault(int(a), []).append(int(b))
    branch = np.mean([len(set(v)) for v in pairs.values()])
    assert branch < 20  # uniform would approach min(64, n_samples)
