#!/usr/bin/env python
"""Docs consistency check (CI tier-1, see scripts/tier1.sh).

Fails when README.md / DESIGN.md / benchmarks/README.md reference files
that don't exist or `repro.*` module paths that don't resolve, or when a
`DESIGN.md §N` reference (in the docs or any src/ docstring) points at a
section DESIGN.md doesn't have. This is what keeps the docs layer from
silently rotting as modules move.

Rules (deliberately conservative — symbols and prose are not checked):
- a whitespace-split token ending in a known file extension (optionally
  with a ``::symbol`` suffix) must exist, resolved against the repo
  root, ``src/repro/`` (so ``core/ota.py`` works), or — for bare
  basenames — the set of all tracked file names;
- a token ending in ``/`` must be an existing directory (same roots);
- a ``repro.foo.bar`` dotted path must resolve to a module or package
  under ``src/``;
- every §N in a ``DESIGN.md §...`` reference must have a ``## §N``
  heading in DESIGN.md.
"""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
DOCS = ["README.md", "DESIGN.md", "benchmarks/README.md"]
EXTS = (".py", ".md", ".sh", ".txt", ".json", ".csv")
STRIP = "`*,;:()[]|<>\"'"


def _all_basenames() -> set:
    names = set()
    for sub in ("src", "tests", "scripts", "examples", "benchmarks"):
        for p in (ROOT / sub).rglob("*"):
            if p.is_file():
                names.add(p.name)
    names.update(p.name for p in ROOT.iterdir() if p.is_file())
    return names


def _resolves(tok: str, basenames: set) -> bool:
    tok = tok.split("::")[0]
    if "/" not in tok:
        return (tok in basenames or (ROOT / tok).exists()
                or (ROOT / "src" / "repro" / tok).exists())
    for base in (ROOT, ROOT / "src" / "repro", ROOT / "src"):
        if (base / tok).exists():
            return True
    return False


def _module_resolves(dotted: str) -> bool:
    rel = pathlib.Path(*dotted.split("."))
    base = ROOT / "src"
    return (base / rel).is_dir() or (base / rel).with_suffix(".py").is_file()


def check_doc(path: pathlib.Path, basenames: set, errors: list) -> None:
    text = path.read_text()
    rel = path.relative_to(ROOT)
    for lineno, line in enumerate(text.splitlines(), 1):
        for raw in line.split():
            tok = raw.strip(STRIP)
            if not tok or tok.startswith(("http://", "https://")):
                continue
            if "*" in tok or "{" in tok:
                continue  # glob / placeholder
            if tok.endswith("/"):
                if not _resolves(tok.rstrip("/"), basenames):
                    errors.append(f"{rel}:{lineno}: missing dir {tok!r}")
            elif tok.split("::")[0].endswith(EXTS):
                if not _resolves(tok, basenames):
                    errors.append(f"{rel}:{lineno}: missing file {tok!r}")
            elif re.fullmatch(r"repro(\.[A-Za-z_][A-Za-z0-9_]*)+", tok):
                # dotted refs may end in a symbol; accept if any prefix
                # with >= 2 segments resolves to a module/package
                parts = tok.split(".")
                if not any(_module_resolves(".".join(parts[:i]))
                           for i in range(2, len(parts) + 1)):
                    errors.append(f"{rel}:{lineno}: stale module {tok!r}")


def check_sections(errors: list) -> None:
    design = (ROOT / "DESIGN.md").read_text()
    have = set(re.findall(r"^##\s*§(\d+)", design, re.M))
    sources = [ROOT / d for d in DOCS]
    sources += sorted((ROOT / "src").rglob("*.py"))
    for path in sources:
        text = path.read_text()
        rel = path.relative_to(ROOT)
        for m in re.finditer(r"DESIGN\.md[^)\n]*", text):
            for sec in re.findall(r"§(\d+)", m.group(0)):
                if sec not in have:
                    errors.append(
                        f"{rel}: reference to DESIGN.md §{sec}, but "
                        f"DESIGN.md has no '## §{sec}' heading")


def main() -> int:
    errors: list = []
    basenames = _all_basenames()
    for doc in DOCS:
        p = ROOT / doc
        if not p.is_file():
            errors.append(f"{doc} is missing")
            continue
        check_doc(p, basenames, errors)
    if (ROOT / "DESIGN.md").is_file():
        check_sections(errors)
    for e in errors:
        print(f"check_docs: {e}", file=sys.stderr)
    if not errors:
        print(f"check_docs: OK ({', '.join(DOCS)})")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
