#!/usr/bin/env bash
# Tier-1 verify (see ROADMAP.md): docs consistency, packed-uplink bench
# smoke (hard-asserted acceptance checks), then the whole suite, stop on
# first failure. Run from the repo root:  bash scripts/tier1.sh [extra
# pytest args...]
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
python scripts/check_docs.py
python benchmarks/bench_aggregation.py --smoke
python -m pytest -x -q "$@"
