#!/usr/bin/env bash
# Tier-1 verify (see ROADMAP.md): docs consistency, packed-uplink bench
# smoke, retrieval-engine bench smoke, streaming-aggregation bench smoke,
# physical-channel bench smoke, telemetry bench smoke, mesh-sharding
# bench smoke (all hard-asserted acceptance checks), the forced-8-device
# multidevice lane, then the whole suite, stop on first failure. Run
# from the repo root:
#   bash scripts/tier1.sh [extra pytest args...]
# CI (.github/workflows/ci.yml) runs these same nine commands (and
# uploads the telemetry smoke's TELEMETRY_* artifacts). The PYTHONPATH
# export is belt-and-braces: pytest (conftest.py) and the benches
# (in-file bootstrap) self-locate src/ when invoked standalone. The
# multidevice lane's tests each re-exec in a child interpreter with
# XLA_FLAGS forcing 8 host devices (tests/_multidevice.py), so the
# hosting pytest process keeps its single default device.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
python scripts/check_docs.py
python benchmarks/bench_aggregation.py --smoke
python benchmarks/bench_retrieval.py --smoke
python benchmarks/bench_streaming.py --smoke
python benchmarks/bench_channel.py --smoke
python benchmarks/bench_obs.py --smoke
python benchmarks/bench_mesh.py --smoke
python -m pytest -q tests/test_distributed.py tests/test_mesh_dataplane.py
python -m pytest -x -q "$@"
