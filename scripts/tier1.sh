#!/usr/bin/env bash
# Tier-1 verify (see ROADMAP.md): the whole suite, stop on first failure.
# Run from the repo root:  bash scripts/tier1.sh [extra pytest args...]
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q "$@"
