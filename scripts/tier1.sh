#!/usr/bin/env bash
# Tier-1 verify (see ROADMAP.md): docs consistency, packed-uplink bench
# smoke, retrieval-engine bench smoke, streaming-aggregation bench smoke,
# physical-channel bench smoke, telemetry bench smoke (all hard-asserted
# acceptance checks), then the whole suite, stop on first failure. Run
# from the repo root:
#   bash scripts/tier1.sh [extra pytest args...]
# CI (.github/workflows/ci.yml) runs these same seven commands (and
# uploads the telemetry smoke's TELEMETRY_* artifacts). The PYTHONPATH
# export is belt-and-braces: pytest (conftest.py) and the benches
# (in-file bootstrap) self-locate src/ when invoked standalone.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
python scripts/check_docs.py
python benchmarks/bench_aggregation.py --smoke
python benchmarks/bench_retrieval.py --smoke
python benchmarks/bench_streaming.py --smoke
python benchmarks/bench_channel.py --smoke
python benchmarks/bench_obs.py --smoke
python -m pytest -x -q "$@"
