"""Make ``repro`` importable without ``PYTHONPATH=src``.

Mirrors the bootstrap in ``benchmarks/bench_aggregation.py`` so pytest, CI,
and bare local invocations agree on the import path (tier-1 previously
relied on ``scripts/tier1.sh`` exporting PYTHONPATH; both entry points are
now self-locating).
"""

import pathlib
import sys

try:
    import repro  # noqa: F401
except ImportError:
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent / "src"))
