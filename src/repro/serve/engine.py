"""Continuous-batching serving engine for the decode shapes.

A minimal-but-real inference runtime over the model zoo's
prefill/decode API:

- fixed ``max_batch`` decode slots backed by one ring-buffer KV cache
  (or SSM state) per slot group — the same cache layout the dry-run's
  ``decode_32k`` / ``long_500k`` shapes exercise;
- a FIFO admission queue; finished/evicted slots are refilled between
  decode steps (continuous batching — no head-of-line blocking on long
  generations);
- per-request state machine QUEUED -> PREFILL -> DECODE -> DONE, with
  max-token and EOS termination.

Single-host execution here; on a pod the jitted step functions are the
ones the launch layer shards (same code path).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.base import ArchConfig
from repro.models.registry import build_model

Pytree = Any


@dataclasses.dataclass
class Request:
    request_id: int
    prompt: np.ndarray  # (P,) int32
    max_new_tokens: int = 32
    eos_id: int = -1  # -1 = never
    # runtime state
    generated: List[int] = dataclasses.field(default_factory=list)
    state: str = "QUEUED"
    slot: int = -1
    enqueue_t: float = 0.0
    finish_t: float = 0.0


class ServeEngine:
    """Continuous-batching decode engine over one model."""

    def __init__(self, cfg: ArchConfig, *, max_batch: int = 8,
                 cache_len: int = 256, window: int = 0, seed: int = 0):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = self.model.init(jax.random.key(seed))
        self.max_batch = max_batch
        self.cache_len = cache_len
        self.window = window
        self.cache = self.model.init_cache(max_batch, cache_len)
        self._decode = jax.jit(
            lambda p, c, b: self.model.decode(p, c, b, window=window))
        self.queue: Deque[Request] = deque()
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.slot_pos = np.zeros(max_batch, np.int64)
        self.next_token = np.zeros(max_batch, np.int32)
        self.steps = 0
        self.completed: List[Request] = []

    # ------------------------------------------------------------------
    def _batch(self):
        """Device inputs for one decode call.

        The host-side ``next_token``/``slot_pos`` buffers are mutated in
        place between calls, and ``jnp.asarray`` on CPU can alias numpy
        memory zero-copy while dispatch is asynchronous — the copies here
        are load-bearing (without them, prefill loops raced their own
        input buffer and wrote the final token at every position).
        """
        return {
            "tokens": jnp.asarray(np.array(self.next_token)).reshape(-1, 1),
            "pos": jnp.asarray(np.array(self.slot_pos, np.int32)),
        }

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        req.enqueue_t = time.time()
        self.queue.append(req)

    def _reset_slot_cache(self, slot: int) -> None:
        """Invalidate one slot's cache entries before admitting a request.

        Batch-axis positions per leaf: attention k/v/pos are
        (L, B, W, ...) -> axis 1; mamba h/conv are (L, B, ...) -> axis 1;
        hybrid ssm_h/ssm_conv are (n_seg, every, B, ...) -> axis 2.
        """
        new = {}
        for name, arr in self.cache.items():
            if name == "pos":
                new[name] = arr.at[:, slot, :].set(-1)
            elif name in ("k", "v"):
                new[name] = arr.at[:, slot].set(0)
            elif name in ("h", "conv"):
                new[name] = arr.at[:, slot].set(0)
            elif name in ("ssm_h", "ssm_conv"):
                new[name] = arr.at[:, :, slot].set(0)
            else:
                new[name] = arr
        self.cache = new

    def _admit(self) -> None:
        for slot in range(self.max_batch):
            if self.slots[slot] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            req.state = "PREFILL"
            req.slot = slot
            self._reset_slot_cache(slot)
            # prefill by stepping the prompt through the decode path token
            # by token for this slot (keeps one compiled step; a batched
            # prefill fast-path is the documented optimisation).
            with obs.span("serve.prefill", slot=slot, tokens=len(req.prompt)):
                for t, tok in enumerate(req.prompt):
                    self.next_token[slot] = tok
                    self.slot_pos[slot] = t
                    logits, self.cache = self._decode(self.params, self.cache,
                                                      self._batch())
            first = int(jnp.argmax(logits[slot]))
            req.generated.append(first)
            self.next_token[slot] = first
            self.slot_pos[slot] = len(req.prompt)
            req.state = "DECODE"
            self.slots[slot] = req

    def _retire(self, slot: int) -> None:
        req = self.slots[slot]
        req.state = "DONE"
        req.finish_t = time.time()
        self.completed.append(req)
        self.slots[slot] = None

    def step(self) -> int:
        """One engine iteration: admit, decode one token for every active
        slot, retire finished requests. Returns #active slots."""
        self._admit()
        active = [s for s in range(self.max_batch) if self.slots[s]]
        if not active:
            return 0
        with obs.span("serve.decode", active=len(active)):
            logits, self.cache = self._decode(self.params, self.cache,
                                              self._batch())
        toks = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        self.steps += 1
        obs.metrics.inc("serve.decode_steps")
        obs.metrics.inc("serve.tokens", len(active))
        for s in active:
            req = self.slots[s]
            tok = int(toks[s])
            req.generated.append(tok)
            self.next_token[s] = tok
            self.slot_pos[s] += 1
            done = (len(req.generated) >= req.max_new_tokens
                    or tok == req.eos_id
                    or self.slot_pos[s] >= self.cache_len - 1)
            if done:
                self._retire(s)
        return len(active)

    def run_until_drained(self, max_steps: int = 10_000) -> List[Request]:
        while (self.queue or any(self.slots)) and self.steps < max_steps:
            self.step()
        return self.completed

    def stats(self) -> Dict[str, float]:
        if not self.completed:
            return {"completed": 0}
        lat = [r.finish_t - r.enqueue_t for r in self.completed]
        toks = sum(len(r.generated) for r in self.completed)
        return {
            "completed": len(self.completed),
            "decode_steps": self.steps,
            "tokens": toks,
            "mean_latency_s": float(np.mean(lat)),
            "tokens_per_step": toks / max(self.steps, 1),
        }
