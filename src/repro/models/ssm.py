"""State-space model blocks: Mamba-1 (falcon-mamba) and Mamba-2/SSD (zamba2).

TPU adaptation (DESIGN.md §4): the CUDA selective-scan kernel does a
sequential recurrence parallelised over channels. On TPU we instead use

- **Mamba-1**: a two-level chunked scan — intra-chunk sequential over
  chunk length L (all chunks advance in lockstep, vectorised over the
  chunk axis) + inter-chunk scan over T/L chunk boundaries, then a second
  intra-chunk pass seeded with the correct boundary states. Sequential
  depth 2L + T/L instead of T; numerically identical to the reference
  recurrence (no inverse-decay terms, so no overflow risk).
- **Mamba-2 (SSD)**: the chunked matmul formulation — intra-chunk
  attention-like matmuls (MXU-friendly) + scalar-per-head inter-chunk
  recurrence.

Both expose a single-step ``*_decode`` path carrying (ssm_state, conv_state).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models.layers import dense_init, rms_norm
from repro.util import constrain

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# depthwise causal conv1d
# ---------------------------------------------------------------------------


def causal_conv1d(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """x: (B, T, C); w: (K, C) depthwise taps; b: (C,). Causal (left pad)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(K):  # K is tiny (4); unrolled shifts beat a conv op here
        out = out + xp[:, i : i + x.shape[1]] * w[i]
    return out + b


def conv1d_decode(
    x_t: jnp.ndarray, conv_state: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One-step depthwise conv. x_t: (B, C); conv_state: (B, K-1, C)."""
    full = jnp.concatenate([conv_state, x_t[:, None]], axis=1)  # (B, K, C)
    out = jnp.einsum("bkc,kc->bc", full, w) + b
    return out, full[:, 1:]


# ---------------------------------------------------------------------------
# Mamba-1 selective scan (diagonal A, per-channel dt)
# ---------------------------------------------------------------------------


def _mamba1_chunked_scan(
    dt: jnp.ndarray,  # (B, T, d)  softplus'd step sizes
    A: jnp.ndarray,  # (d, N)     negative
    Bm: jnp.ndarray,  # (B, T, N)
    Cm: jnp.ndarray,  # (B, T, N)
    x: jnp.ndarray,  # (B, T, d)
    h0: jnp.ndarray,  # (B, d, N) initial state
    chunk: int = 64,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y (B,T,d), h_final (B,d,N)). fp32 internally."""
    B_, T, d = x.shape
    N = A.shape[1]
    L = min(chunk, T)
    n_chunks = -(-T // L)
    pad = n_chunks * L - T

    def pad_t(a):
        return jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2)) if pad else a

    dt_c = pad_t(dt).reshape(B_, n_chunks, L, d).astype(jnp.float32)
    B_c = pad_t(Bm).reshape(B_, n_chunks, L, N).astype(jnp.float32)
    C_c = pad_t(Cm).reshape(B_, n_chunks, L, N).astype(jnp.float32)
    x_c = pad_t(x).reshape(B_, n_chunks, L, d).astype(jnp.float32)
    Af = A.astype(jnp.float32)

    # scan over intra-chunk position; all chunks in lockstep.
    def intra(h, inputs, emit: bool):
        dt_t, B_t, x_t = inputs[:3]  # (B, NC, d), (B, NC, N), (B, NC, d)
        a_t = jnp.exp(dt_t[..., None] * Af)  # (B, NC, d, N); A<0 => a in (0,1]
        b_t = (dt_t * x_t)[..., None] * B_t[:, :, None, :]  # (B, NC, d, N)
        h = a_t * h + b_t
        if emit:
            C_t = inputs[3]  # (B, NC, N)
            y_t = jnp.einsum("bcdn,bcn->bcd", h, C_t)
            return h, y_t
        return h, a_t  # emit per-step decay for chunk-decay product

    # ---- pass 1: chunk-local final states (h0 = 0) + chunk decay products
    def p1_step(carry, t):
        h, adec = carry
        inp = (dt_c[:, :, t], B_c[:, :, t], x_c[:, :, t])
        h, a_t = intra(h, inp, emit=False)
        return (h, adec * a_t), None

    h_zero = jnp.zeros((B_, n_chunks, d, N), jnp.float32)
    (h_local, a_chunk), _ = jax.lax.scan(
        p1_step, (h_zero, jnp.ones_like(h_zero)), jnp.arange(L)
    )

    # ---- pass 2: inter-chunk recurrence over chunk boundaries
    def p2_step(H, c):
        H_next = a_chunk[:, c] * H + h_local[:, c]
        return H_next, H  # emit state *entering* chunk c

    h_final, H_in = jax.lax.scan(p2_step, h0.astype(jnp.float32), jnp.arange(n_chunks))
    H_in = H_in.transpose(1, 0, 2, 3)  # (B, NC, d, N)

    # ---- pass 3: recompute with correct seeds, emitting outputs
    def p3_step(h, t):
        inp = (dt_c[:, :, t], B_c[:, :, t], x_c[:, :, t], C_c[:, :, t])
        h, y_t = intra(h, inp, emit=True)
        return h, y_t

    _, ys = jax.lax.scan(p3_step, H_in, jnp.arange(L))  # (L, B, NC, d)
    y = ys.transpose(1, 2, 0, 3).reshape(B_, n_chunks * L, d)[:, :T]
    return y, h_final


def init_mamba1(key, cfg: ArchConfig, dtype) -> Params:
    d = cfg.d_model
    di = cfg.resolved_d_inner()
    N = cfg.ssm_state
    R = cfg.resolved_dt_rank()
    K = cfg.ssm_conv
    ks = jax.random.split(key, 8)
    # S4D-real initialisation for A
    A = jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None, :], (di, 1))
    dt_bias = jnp.log(jnp.expm1(jnp.exp(
        jax.random.uniform(ks[6], (di,), jnp.float32) * (jnp.log(0.1) - jnp.log(0.001))
        + jnp.log(0.001)
    )))
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di), dtype),
        "conv_w": (jax.random.normal(ks[1], (K, di), jnp.float32)
                   * (K ** -0.5)).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[2], (di, R + 2 * N), dtype),
        "dt_proj": dense_init(ks[3], (R, di), dtype, scale=R ** -0.5),
        "dt_bias": dt_bias,
        "A_log": jnp.log(A),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], (di, d), dtype),
    }


def _mamba1_inner(p: Params, xz: jnp.ndarray, cfg: ArchConfig, h0, conv_state=None):
    """Shared pre/post processing. xz: (B, T, 2*di) from in_proj."""
    di = cfg.resolved_d_inner()
    N = cfg.ssm_state
    R = cfg.resolved_dt_rank()
    x, z = xz[..., :di], xz[..., di:]
    x = constrain(x, P(("pod", "data"), None, "model"))
    if conv_state is None:
        K = p["conv_w"].shape[0]
        # conv tail = last K-1 pre-conv inputs (left-padded if T < K-1);
        # this is the conv state a subsequent decode step needs.
        tail = (jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))[:, -(K - 1):]
                if K > 1 else x[:, :0])
        x = causal_conv1d(x, p["conv_w"], p["conv_b"])
        new_conv = tail
    else:
        xc, new_conv = conv1d_decode(x[:, 0], conv_state, p["conv_w"], p["conv_b"])
        x = xc[:, None]
    x = jax.nn.silu(x)
    proj = x @ p["x_proj"]  # (B, T, R + 2N)
    dt = jax.nn.softplus(proj[..., :R] @ p["dt_proj"] + p["dt_bias"])
    Bm = proj[..., R : R + N]
    Cm = proj[..., R + N :]
    A = -jnp.exp(p["A_log"])
    y, h_final = _mamba1_chunked_scan(dt, A, Bm, Cm, x, h0)
    y = y + x.astype(jnp.float32) * p["D"]
    y = y.astype(xz.dtype) * jax.nn.silu(z)
    out = y @ p["out_proj"]
    return out, h_final, new_conv


def mamba1_block(p: Params, x: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    B = x.shape[0]
    di, N = cfg.resolved_d_inner(), cfg.ssm_state
    h0 = jnp.zeros((B, di, N), jnp.float32)
    out, _, _ = _mamba1_inner(p, x @ p["in_proj"], cfg, h0)
    return out


def mamba1_decode(
    p: Params, x: jnp.ndarray, cfg: ArchConfig, state: Dict[str, jnp.ndarray]
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """x: (B, 1, d); state = {"h": (B,di,N), "conv": (B,K-1,di)}."""
    out, h_final, new_conv = _mamba1_inner(
        p, x @ p["in_proj"], cfg, state["h"], conv_state=state["conv"]
    )
    return out, {"h": h_final, "conv": new_conv}


# ---------------------------------------------------------------------------
# Mamba-2 (SSD) — scalar-per-head decay, chunked matmul form
# ---------------------------------------------------------------------------


def init_mamba2(key, cfg: ArchConfig, dtype) -> Params:
    d = cfg.d_model
    di = cfg.resolved_d_inner()
    H = cfg.resolved_ssm_heads()
    N = cfg.ssm_state
    K = cfg.ssm_conv
    conv_dim = di + 2 * N  # x, B, C go through the conv
    ks = jax.random.split(key, 8)
    A = jnp.exp(
        jax.random.uniform(ks[5], (H,), jnp.float32)
        * (jnp.log(16.0) - jnp.log(1.0)) + jnp.log(1.0)
    )
    dt_bias = jnp.log(jnp.expm1(jnp.exp(
        jax.random.uniform(ks[6], (H,), jnp.float32) * (jnp.log(0.1) - jnp.log(0.001))
        + jnp.log(0.001)
    )))
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di + 2 * N + H), dtype),
        "conv_w": (jax.random.normal(ks[1], (K, conv_dim), jnp.float32)
                   * (K ** -0.5)).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(A),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": dt_bias,
        "gate_norm": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[2], (di, d), dtype),
    }


def _ssd_scan(
    x: jnp.ndarray,  # (B, T, H, P) head inputs
    dt: jnp.ndarray,  # (B, T, H) softplus'd
    A: jnp.ndarray,  # (H,) negative
    Bm: jnp.ndarray,  # (B, T, N)
    Cm: jnp.ndarray,  # (B, T, N)
    h0: jnp.ndarray,  # (B, H, P, N)
    chunk: int = 64,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """SSD chunked algorithm. Returns (y (B,T,H,P), h_final)."""
    B_, T, H, Pd = x.shape
    N = Bm.shape[-1]
    L = min(chunk, T)
    n_chunks = -(-T // L)
    pad = n_chunks * L - T

    def pad_t(a):
        return jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2)) if pad else a

    xf = pad_t(x).reshape(B_, n_chunks, L, H, Pd).astype(jnp.float32)
    dtf = pad_t(dt).reshape(B_, n_chunks, L, H).astype(jnp.float32)
    Bf = pad_t(Bm).reshape(B_, n_chunks, L, N).astype(jnp.float32)
    Cf = pad_t(Cm).reshape(B_, n_chunks, L, N).astype(jnp.float32)

    la = dtf * A  # (B, NC, L, H) log-decay per step (negative)
    cum = jnp.cumsum(la, axis=2)  # inclusive cumulative log-decay

    # intra-chunk attention-like term:
    # M[t,s] = exp(cum[t]-cum[s]) for t>=s  (<=1, safe)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,NC,L,L,H)
    tri = jnp.tril(jnp.ones((L, L), bool))
    M = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)
    scores = jnp.einsum("bcln,bcmn->bclm", Cf, Bf)  # (B,NC,L,L)
    xdt = xf * dtf[..., None]  # (B,NC,L,H,P)
    y_intra = jnp.einsum("bclm,bclmh,bcmhp->bclhp", scores, M, xdt)

    # chunk-final states with zero seed: S_c = sum_s exp(cum[L-1]-cum[s]) * B_s x_s dt_s
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)  # (B,NC,L,H)
    S_c = jnp.einsum("bclh,bcln,bclhp->bchpn", decay_to_end, Bf, xdt)

    # inter-chunk recurrence: scalar chunk decay per head
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # (B, NC, H)

    def step(Hc, c):
        H_next = chunk_decay[:, c][:, :, None, None] * Hc + S_c[:, c]
        return H_next, Hc

    h_final, H_in = jax.lax.scan(step, h0.astype(jnp.float32), jnp.arange(n_chunks))
    H_in = H_in.transpose(1, 0, 2, 3, 4)  # (B, NC, H, P, N)

    # contribution of entering state: y_t += C_t^T (exp(cum[t]) * H_in)
    decay_from_start = jnp.exp(cum)  # (B,NC,L,H)
    y_inter = jnp.einsum("bcln,bclh,bchpn->bclhp", Cf, decay_from_start, H_in)

    y = (y_intra + y_inter).reshape(B_, n_chunks * L, H, Pd)[:, :T]
    return y, h_final


def _mamba2_split(p: Params, zxbcdt: jnp.ndarray, cfg: ArchConfig):
    di = cfg.resolved_d_inner()
    N = cfg.ssm_state
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di : di + di + 2 * N]
    dt_raw = zxbcdt[..., di + di + 2 * N :]  # (B, T, H)
    return z, xBC, dt_raw


def mamba2_block(p: Params, x_in: jnp.ndarray, cfg: ArchConfig,
                 return_state: bool = False):
    """Returns (out, state|None); state = {"h", "conv"} for decode priming."""
    B_, T, _ = x_in.shape
    di = cfg.resolved_d_inner()
    N = cfg.ssm_state
    H = cfg.resolved_ssm_heads()
    Pd = di // H
    K = p["conv_w"].shape[0]
    z, xBC, dt_raw = _mamba2_split(p, x_in @ p["in_proj"], cfg)
    conv_tail = (jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))[:, -(K - 1):]
                 if K > 1 else xBC[:, :0])
    xBC = jax.nn.silu(causal_conv1d(xBC, p["conv_w"], p["conv_b"]))
    x = xBC[..., :di].reshape(B_, T, H, Pd)
    x = constrain(x, P(("pod", "data"), None, "model", None))
    Bm = xBC[..., di : di + N]
    Cm = xBC[..., di + N :]
    dt = jax.nn.softplus(dt_raw + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    h0 = jnp.zeros((B_, H, Pd, N), jnp.float32)
    y, h_final = _ssd_scan(x, dt, A, Bm, Cm, h0)
    y = y + x.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(B_, T, di).astype(x_in.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    out = y @ p["out_proj"]
    if return_state:
        return out, {"h": h_final, "conv": conv_tail}
    return out, None


def mamba2_decode(
    p: Params, x_in: jnp.ndarray, cfg: ArchConfig, state: Dict[str, jnp.ndarray]
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Single-step SSD recurrence. state={"h": (B,H,P,N), "conv": (B,K-1,conv_dim)}."""
    B_ = x_in.shape[0]
    di = cfg.resolved_d_inner()
    N = cfg.ssm_state
    H = cfg.resolved_ssm_heads()
    Pd = di // H
    z, xBC, dt_raw = _mamba2_split(p, x_in @ p["in_proj"], cfg)
    xBC_t, new_conv = conv1d_decode(xBC[:, 0], state["conv"], p["conv_w"], p["conv_b"])
    xBC_t = jax.nn.silu(xBC_t)
    x = xBC_t[..., :di].reshape(B_, H, Pd)
    Bm = xBC_t[..., di : di + N]
    Cm = xBC_t[..., di + N :]
    dt = jax.nn.softplus(dt_raw[:, 0] + p["dt_bias"])  # (B, H)
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt * A)  # (B, H)
    h = state["h"].astype(jnp.float32)
    upd = jnp.einsum("bhp,bn->bhpn", x.astype(jnp.float32) * dt[..., None],
                     Bm.astype(jnp.float32))
    h_new = decay[:, :, None, None] * h + upd
    y = jnp.einsum("bhpn,bn->bhp", h_new, Cm.astype(jnp.float32))
    y = y + x.astype(jnp.float32) * p["D"][None, :, None]
    y = y.reshape(B_, 1, di).astype(x_in.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    return y @ p["out_proj"], {"h": h_new, "conv": new_conv}
