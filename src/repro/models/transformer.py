"""Generic decoder-only LM covering the dense / moe / vlm / ssm families.

Layers are *stacked* (every per-layer param has a leading ``n_layers`` axis)
and applied with ``jax.lax.scan`` so 61–95-layer production configs lower to
compact HLO. ``cfg.remat`` wraps the scanned block in ``jax.checkpoint``.

Three entry points per model:
- ``lm_loss(params, batch, cfg)``      — training loss (chunked logits).
- ``prefill(params, batch, cfg)``      — full-sequence forward + KV cache.
- ``decode_step(params, cache, batch, cfg)`` — one token with cache.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import ssm as S
from repro.util import constrain, dtype_of

Params = Dict[str, Any]

LOSS_CHUNK = 512  # sequence chunk for logit materialisation


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_layer(key, cfg: ArchConfig, dtype) -> Params:
    """One layer's params (unstacked)."""
    ks = jax.random.split(key, 4)
    if cfg.family == "ssm":
        return {"norm": jnp.ones((cfg.d_model,), dtype),
                "mamba": S.init_mamba1(ks[0], cfg, dtype)}
    p: Params = {
        "attn_norm": jnp.ones((cfg.d_model,), dtype),
        "mlp_norm": jnp.ones((cfg.d_model,), dtype),
        "attn": L.init_attention(ks[0], cfg, dtype),
    }
    if cfg.family == "moe":
        p["moe"] = L.init_moe(ks[1], cfg, dtype)
    else:
        p["mlp"] = L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype)
    return p


def init_lm(key, cfg: ArchConfig) -> Params:
    dtype = dtype_of(cfg.param_dtype)
    k_embed, k_layers, k_head = jax.random.split(key, 3)
    # stacked layer params: vmap the per-layer init over layer keys
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    stacked = jax.vmap(lambda k: _init_layer(k, cfg, dtype))(layer_keys)
    p: Params = {
        "embed": L.embed_init(k_embed, (cfg.vocab_size, cfg.d_model), dtype),
        "layers": stacked,
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = L.dense_init(k_head, (cfg.d_model, cfg.vocab_size), dtype)
    if cfg.frontend == "vision":
        # projector from stub patch embeddings to d_model
        p["vis_proj"] = L.dense_init(
            jax.random.fold_in(key, 11), (cfg.frontend_dim, cfg.d_model), dtype
        )
    return p


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------


def _block(p: Params, x, cfg: ArchConfig, positions, window: int,
           differentiable: bool = True):
    """Full-sequence layer. Returns (x, aux, (k, v)).

    NOTE (§Perf iter 2, refuted): Megatron-style sequence parallelism via
    bare sharding constraints (residual stream P(dp, "model", None) with
    gather/scatter pairs around the TP matmuls) triggers "involuntary full
    rematerialization" in the GSPMD partitioner wherever the seq-sharding
    meets the flash-attention chunk reshapes — measured all-gather bytes
    went 46 GB -> 24 TB on deepseek-67b. Reverted; a Shardy-based retry is
    the documented follow-up.
    """
    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "ssm":
        x = x + S.mamba1_block(p["mamba"], L.rms_norm(x, p["norm"], cfg.norm_eps), cfg)
        return x, aux, None
    h, kv = L.attention_block(
        p["attn"], L.rms_norm(x, p["attn_norm"], cfg.norm_eps), cfg, positions,
        causal=True, window=window, differentiable=differentiable,
    )
    x = x + h
    hn = L.rms_norm(x, p["mlp_norm"], cfg.norm_eps)
    if cfg.family == "moe":
        h2, aux = L.moe_block(p["moe"], hn, cfg)
    else:
        h2 = L.mlp_block(p["mlp"], hn)
    return x + h2, aux, kv


def _block_decode(p: Params, x, cfg: ArchConfig, pos, cache, window: int):
    if cfg.family == "ssm":
        h, new_state = S.mamba1_decode(
            p["mamba"], L.rms_norm(x, p["norm"], cfg.norm_eps), cfg, cache
        )
        return x + h, new_state
    h, new_cache = L.attention_decode_block(
        p["attn"], L.rms_norm(x, p["attn_norm"], cfg.norm_eps), cfg, pos, cache,
        window=window,
    )
    x = x + h
    hn = L.rms_norm(x, p["mlp_norm"], cfg.norm_eps)
    if cfg.family == "moe":
        h2, _ = L.moe_block(p["moe"], hn, cfg)
    else:
        h2 = L.mlp_block(p["mlp"], hn)
    return x + h2, new_cache


# ---------------------------------------------------------------------------
# embeddings / positions
# ---------------------------------------------------------------------------


def _positions(cfg: ArchConfig, B: int, S_: int, offset=0):
    pos = jnp.arange(S_, dtype=jnp.int32)[None, :] + offset
    pos = jnp.broadcast_to(pos, (B, S_))
    if cfg.mrope:
        # stub frontend: all three M-RoPE streams share sequential positions
        # (real VLM would give patch rows/cols distinct h/w streams).
        return jnp.broadcast_to(pos[:, None, :], (B, 3, S_))
    return pos


def _embed_inputs(params: Params, batch: Dict[str, jnp.ndarray], cfg: ArchConfig):
    """tokens (+ optional stub modality embeddings) -> (B, S_total, d)."""
    x = params["embed"][batch["tokens"]]
    if cfg.frontend == "vision" and "patches" in batch:
        vis = batch["patches"].astype(x.dtype) @ params["vis_proj"]
        x = jnp.concatenate([vis, x], axis=1)
    return x.astype(dtype_of(cfg.compute_dtype))


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------


def _run_layers(params: Params, x, cfg: ArchConfig, positions, window: int,
                collect_kv: bool = False, differentiable: bool = True):
    """Scan the stacked layers. Returns (x, aux_total, kv_stack|None)."""

    def body(carry, layer_p):
        xc, aux_acc = carry
        xo, aux, kv = _block(layer_p, xc, cfg, positions, window,
                             differentiable)
        out = kv if collect_kv else None
        return (xo, aux_acc + aux), out

    fn = jax.checkpoint(body) if cfg.remat else body
    carry0 = (x, jnp.zeros((), jnp.float32))
    if cfg.unroll_layers:  # cost-calibration mode: true per-layer HLO
        carry, kv_list = carry0, []
        for i in range(cfg.n_layers):
            layer_p = jax.tree.map(lambda a: a[i], params["layers"])
            carry, out = fn(carry, layer_p)
            kv_list.append(out)
        x, aux = carry
        kvs = (jax.tree.map(lambda *xs: jnp.stack(xs), *kv_list)
               if collect_kv else None)
        return x, aux, kvs
    (x, aux), kvs = jax.lax.scan(fn, carry0, params["layers"])
    return x, aux, kvs


def lm_logits_and_aux(params: Params, batch, cfg: ArchConfig):
    x = _embed_inputs(params, batch, cfg)
    B, S_total = x.shape[0], x.shape[1]
    positions = _positions(cfg, B, S_total)
    x = constrain(x, P(("pod", "data"), None, None))
    x, aux, _ = _run_layers(params, x, cfg, positions, window=0)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return x, head, aux


def lm_loss(params: Params, batch: Dict[str, jnp.ndarray], cfg: ArchConfig):
    """Next-token CE on the token segment; logits materialised per chunk."""
    x, head, aux = lm_logits_and_aux(params, batch, cfg)
    B = x.shape[0]
    S_tok = batch["tokens"].shape[1]
    x_tok = x[:, -S_tok:]  # strip modality prefix if present
    # shift: predict tokens[t+1] from position t
    h = x_tok[:, :-1]
    targets = batch.get("labels", batch["tokens"])[:, 1:]
    mask = batch.get("mask", jnp.ones_like(targets))[..., : targets.shape[1]]
    T = h.shape[1]
    chunk = min(cfg.loss_chunk, T)
    n = -(-T // chunk)
    pad = n * chunk - T
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    hc = h.reshape(B, n, chunk, -1).swapaxes(0, 1)
    tc = targets.reshape(B, n, chunk).swapaxes(0, 1)
    mc = mask.reshape(B, n, chunk).swapaxes(0, 1)


    def ce_chunk(carry, inp):
        hh, tt, mm = inp
        # vocab-parallel CE: logits stay sharded on the vocab dim (lm_head
        # is P(None, "model")); logsumexp reduces locally then all-reduces
        # only the (B, chunk) scalars.
        logits = (hh @ head).astype(jnp.float32)
        # batch stays sharded over (pod, data) — a None there would force
        # a full logits all-gather across the data axis (§Perf iter 1b)
        logits = constrain(logits, P(("pod", "data"), None, "model"))
        logz = jax.nn.logsumexp(logits, axis=-1)
        # gold logit = <h, head[:, target]>: gather head *columns* (B·c·d
        # bytes) instead of touching the (B, c, V) logits again.
        cols = jnp.take(head, tt.reshape(-1), axis=1)  # (d, B*c)
        cols = cols.reshape(head.shape[0], *tt.shape)  # (d, B, c)
        gold = jnp.einsum("bcd,dbc->bc", hh.astype(jnp.float32),
                          cols.astype(jnp.float32))
        nll = (logz - gold) * mm
        return (carry[0] + nll.sum(), carry[1] + mm.sum()), None

    (tot, cnt), _ = jax.lax.scan(ce_chunk, (jnp.zeros(()), jnp.zeros(())), (hc, tc, mc))
    loss = tot / jnp.maximum(cnt, 1.0)
    return loss + cfg.router_aux_coef * aux / max(cfg.n_layers, 1), {
        "ce": loss, "aux": aux,
    }


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def init_decode_cache(cfg: ArchConfig, B: int, cache_len: int) -> Params:
    """Per-layer cache stacked on the layer axis."""
    dt = dtype_of(cfg.param_dtype)
    nl = cfg.n_layers
    if cfg.family == "ssm":
        di, N, K = cfg.resolved_d_inner(), cfg.ssm_state, cfg.ssm_conv
        return {
            "h": jnp.zeros((nl, B, di, N), jnp.float32),
            "conv": jnp.zeros((nl, B, K - 1, di), dt),
        }
    KV, Dh = cfg.n_kv_heads, cfg.resolved_head_dim()
    return {
        "k": jnp.zeros((nl, B, cache_len, KV, Dh), dt),
        "v": jnp.zeros((nl, B, cache_len, KV, Dh), dt),
        "pos": jnp.full((nl, B, cache_len), -1, jnp.int32),
    }


def decode_step(params: Params, cache, batch, cfg: ArchConfig,
                *, window: int = 0):
    """One token. batch = {"tokens": (B,1), "pos": (B,)}. Returns (logits, cache)."""
    x = params["embed"][batch["tokens"]].astype(dtype_of(cfg.compute_dtype))
    pos = batch["pos"]

    def body(x_c, scanned):
        layer_p, layer_cache = scanned
        x_out, new_cache = _block_decode(layer_p, x_c, cfg, pos, layer_cache, window)
        return x_out, new_cache

    if cfg.unroll_layers:
        new_caches = []
        for i in range(cfg.n_layers):
            sl = jax.tree.map(lambda a: a[i], (params["layers"], cache))
            x, nc = body(x, sl)
            new_caches.append(nc)
        new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *new_caches)
    else:
        x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x[:, 0] @ head).astype(jnp.float32)
    return logits, new_cache


def prefill(params: Params, batch, cfg: ArchConfig):
    """Full forward; returns (last-position logits, primed KV cache).

    The cache is filled from the per-layer K/V collected during the scan.
    """
    x = _embed_inputs(params, batch, cfg)
    B, S_total = x.shape[0], x.shape[1]
    positions = _positions(cfg, B, S_total)
    x = constrain(x, P(("pod", "data"), None, None))
    if cfg.family == "ssm":
        # run layers sequentially collecting final states: reuse block fn but
        # capture states via a scan emitting them.
        def body(xc, layer_p):
            xn = L.rms_norm(xc, layer_p["norm"], cfg.norm_eps)
            di, N = cfg.resolved_d_inner(), cfg.ssm_state
            h0 = jnp.zeros((B, di, N), jnp.float32)
            out, h_fin, conv_tail = S._mamba1_inner(
                layer_p["mamba"], xn @ layer_p["mamba"]["in_proj"], cfg, h0
            )
            return xc + out, (h_fin, conv_tail)

        if cfg.unroll_layers:
            emits = []
            for i in range(cfg.n_layers):
                layer_p = jax.tree.map(lambda a: a[i], params["layers"])
                x, em = body(x, layer_p)
                emits.append(em)
            h_stack, conv_stack = jax.tree.map(
                lambda *xs: jnp.stack(xs), *emits)
        else:
            x, (h_stack, conv_stack) = jax.lax.scan(body, x, params["layers"])
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = (x[:, -1] @ head).astype(jnp.float32)
        cache = {"h": h_stack, "conv": conv_stack}
        return logits, cache
    x, aux, kvs = _run_layers(params, x, cfg, positions, window=0,
                              collect_kv=True, differentiable=False)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x[:, -1] @ head).astype(jnp.float32)
    k_stack, v_stack = kvs  # (nl, B, S, KV, Dh)
    cache = {
        "k": k_stack,
        "v": v_stack,
        "pos": jnp.broadcast_to(
            jnp.arange(S_total, dtype=jnp.int32)[None, None, :],
            (cfg.n_layers, B, S_total),
        ),
    }
    return logits, cache
