"""Core neural layers: norms, RoPE/M-RoPE, chunked attention, MLP, MoE.

Design rules (see DESIGN.md §3/§4):
- pure functions over param dicts (pytrees); no module framework.
- attention is computed flash-style (online softmax over KV chunks inside a
  ``lax.scan``) so 32k-token prefill never materialises an S×S score matrix.
- MoE uses sort-based capacity dispatch into an (E, C, d) buffer — the
  TPU-native formulation (batched expert einsum on the MXU), with a
  sharding constraint placing experts on the ``model`` mesh axis.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.util import get_abstract_mesh

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Initialisers
# ---------------------------------------------------------------------------


def dense_init(key, shape, dtype, scale: Optional[float] = None):
    """Truncated-normal fan-in init."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else fan_in ** -0.5
    w = jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
    return (w * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x, weight, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(dt)


def layer_norm(x, weight, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings (RoPE + sectioned M-RoPE)
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies for the rotary halves (head_dim//2,)."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Standard RoPE. x: (..., S, H, D); positions: (..., S) int32."""
    if theta <= 0:
        return x
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)  # (half,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    return jnp.concatenate([out1, out2], axis=-1).astype(x.dtype)


def apply_mrope(
    x: jnp.ndarray,
    positions: jnp.ndarray,
    theta: float,
    sections: Tuple[int, ...],
) -> jnp.ndarray:
    """Qwen2-VL sectioned M-RoPE.

    x: (B, S, H, D). positions: (B, 3, S) — temporal/height/width streams.
    ``sections`` partitions the rotary half-dim; section i rotates with
    position stream i. sum(sections) == D // 2.
    """
    if theta <= 0:
        return x
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(x.shape[-1], theta)  # (half,)
    # angles per stream: (B, 3, S, half)
    angles_all = positions[..., None].astype(jnp.float32) * freqs
    # select stream per frequency-section: section_ids[h] in {0,1,2}
    section_ids = jnp.repeat(
        jnp.arange(len(sections)), jnp.array(sections), total_repeat_length=half
    )  # (half,) static
    sel = jax.nn.one_hot(section_ids, len(sections), dtype=jnp.float32)  # (half, 3)
    angles = jnp.einsum("bksh,hk->bsh", angles_all, sel)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    return jnp.concatenate([out1, out2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Chunked (flash-style) attention
# ---------------------------------------------------------------------------


NEG_INF = -1e30


def _attn_chunk_mask(
    q_pos: jnp.ndarray, k_pos: jnp.ndarray, causal: bool, window: int
) -> jnp.ndarray:
    """(Qc, Kc) boolean mask: True = attend."""
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m &= q_pos[:, None] >= k_pos[None, :]
    if window > 0:
        m &= q_pos[:, None] - k_pos[None, :] < window
    return m


def chunked_attention(
    q: jnp.ndarray,  # (B, Sq, H, D)
    k: jnp.ndarray,  # (B, Sk, KV, D)
    v: jnp.ndarray,  # (B, Sk, KV, D)
    *,
    causal: bool = True,
    window: int = 0,
    q_chunk: int = 1024,
    k_chunk: int = 1024,
    q_offset: int = 0,
    block_skip: bool = True,
    differentiable: bool = True,
    max_unroll: int = 8,
    unroll_kv: bool = False,
) -> jnp.ndarray:
    """Memory-efficient attention with online softmax (flash-style).

    Never materialises more than (B, KV, G, Qc, Kc) scores. GQA is handled
    by grouping query heads over KV heads. Causal block skip — not
    computing fully-masked KV blocks, which halves causal FLOPs vs a
    masked-full implementation — comes in two flavours:

    - **unrolled** (differentiable, used in training): python-unrolled
      query blocks, each scanning only its static KV prefix. HLO grows
      ~n_q-fold, so only used when n_q <= max_unroll.
    - **dynamic** (non-differentiable, used in prefill): scanned query
      blocks with a bounded ``fori_loop`` over KV blocks — compact HLO at
      any sequence length, but reverse-mode AD rejects the dynamic trip
      count.

    Otherwise falls back to the masked full scan (always differentiable).
    """
    B, Sq, H, D = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    q_chunk = min(q_chunk, Sq)
    k_chunk = min(k_chunk, Sk)
    # pad to multiples
    n_q = -(-Sq // q_chunk)
    n_k = -(-Sk // k_chunk)
    pad_q = n_q * q_chunk - Sq
    pad_k = n_k * k_chunk - Sk
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0))) if pad_q else q
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else k
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else v

    scale = D ** -0.5
    # (n_q, B, Qc, KV, G, D)
    qs = qp.reshape(B, n_q, q_chunk, KV, G, D).transpose(1, 0, 2, 3, 4, 5)
    ks = kp.reshape(B, n_k, k_chunk, KV, D).transpose(1, 0, 2, 3, 4)
    vs = vp.reshape(B, n_k, k_chunk, KV, D).transpose(1, 0, 2, 3, 4)

    q_pos_base = q_offset + jnp.arange(n_q * q_chunk).reshape(n_q, q_chunk)
    k_pos_base = jnp.arange(n_k * k_chunk).reshape(n_k, k_chunk)

    def kv_step_fn(q_blk, q_pos):
        def kv_step(acc, ki_inputs):
            k_blk, v_blk, k_pos = ki_inputs
            m_prev, l_prev, o_prev = acc
            # scores: (B, KV, G, Qc, Kc). Operands stay in their native
            # dtype (bf16 on TPU) with f32 MXU accumulation — explicit f32
            # casts here would double the HBM traffic of the QK^T and PV
            # matmuls (measured in EXPERIMENTS.md §Perf).
            s = jnp.einsum("bqkgd,bckd->bkgqc", q_blk, k_blk,
                           preferred_element_type=jnp.float32) * scale
            mask = _attn_chunk_mask(q_pos, k_pos, causal, window)
            mask &= (k_pos < Sk)[None, :]  # key padding
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_prev - m_new)
            l_new = l_prev * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqc,bckd->bkgqd", p.astype(v_blk.dtype), v_blk,
                            preferred_element_type=jnp.float32)
            o_new = o_prev * corr[..., None] + pv
            return (m_new, l_new, o_new), None

        return kv_step

    def init_acc():
        m0 = jnp.full((B, KV, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, q_chunk), jnp.float32)
        o0 = jnp.zeros((B, KV, G, q_chunk, D), jnp.float32)
        return m0, l0, o0

    def finish(m, l, o):
        return (o / jnp.maximum(l[..., None], 1e-30)).astype(q.dtype)

    skippable = causal and block_skip and q_offset == 0 and Sq == Sk \
        and window == 0

    if skippable and (unroll_kv or (differentiable and n_q <= max_unroll)):
        # --- unrolled structural skip (differentiable)
        outs = []
        for qi in range(n_q):
            step = kv_step_fn(qs[qi], q_pos_base[qi])
            if unroll_kv:  # full unroll: true HLO cost visible to XLA
                acc = init_acc()
                for kj in range(qi + 1):
                    acc, _ = step(acc, (ks[kj], vs[kj], k_pos_base[kj]))
                m, l, o = acc
            else:
                (m, l, o), _ = jax.lax.scan(
                    step, init_acc(),
                    (ks[: qi + 1], vs[: qi + 1], k_pos_base[: qi + 1]))
            outs.append(finish(m, l, o))
        outs = jnp.stack(outs)  # (n_q, B, KV, G, Qc, D)
    elif skippable and not differentiable:
        # --- dynamic structural skip (prefill; no reverse-mode AD)
        def q_block(carry, qi_inputs):
            qi, q_blk, q_pos = qi_inputs
            step = kv_step_fn(q_blk, q_pos)

            def body(kj, acc):
                inp = (
                    jax.lax.dynamic_index_in_dim(ks, kj, 0, keepdims=False),
                    jax.lax.dynamic_index_in_dim(vs, kj, 0, keepdims=False),
                    jax.lax.dynamic_index_in_dim(k_pos_base, kj, 0,
                                                 keepdims=False),
                )
                acc2, _ = step(acc, inp)
                return acc2

            m, l, o = jax.lax.fori_loop(0, qi + 1, body, init_acc())
            return carry, finish(m, l, o)

        _, outs = jax.lax.scan(
            q_block, None, (jnp.arange(n_q), qs, q_pos_base))
    elif unroll_kv:
        # --- fully unrolled masked attention (cost calibration)
        outs_l = []
        for qi in range(n_q):
            step = kv_step_fn(qs[qi], q_pos_base[qi])
            acc = init_acc()
            for kj in range(n_k):
                acc, _ = step(acc, (ks[kj], vs[kj], k_pos_base[kj]))
            outs_l.append(finish(*acc))
        outs = jnp.stack(outs_l)
    else:
        # --- masked full scan (fallback; differentiable)
        def q_block(carry, qi_inputs):
            q_blk, q_pos = qi_inputs
            step = kv_step_fn(q_blk, q_pos)
            (m, l, o), _ = jax.lax.scan(step, init_acc(),
                                        (ks, vs, k_pos_base))
            return carry, finish(m, l, o)

        _, outs = jax.lax.scan(q_block, None, (qs, q_pos_base))

    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, n_q * q_chunk, H, D)
    return out[:, :Sq]


def decode_attention(
    q: jnp.ndarray,  # (B, 1, H, D)
    k_cache: jnp.ndarray,  # (B, W, KV, D)
    v_cache: jnp.ndarray,  # (B, W, KV, D)
    cache_pos: jnp.ndarray,  # (B, W) int32, -1 = empty
    pos: jnp.ndarray,  # (B,) current absolute position
    *,
    window: int = 0,
) -> jnp.ndarray:
    """Single-token attention against a (possibly ring-buffer) KV cache."""
    B, W, KV, D = k_cache.shape
    H = q.shape[2]
    G = H // KV
    scale = D ** -0.5
    qh = q.reshape(B, KV, G, D).astype(jnp.float32)
    s = jnp.einsum("bkgd,bwkd->bkgw", qh, k_cache.astype(jnp.float32)) * scale
    valid = (cache_pos >= 0) & (cache_pos <= pos[:, None])
    if window > 0:
        valid &= pos[:, None] - cache_pos < window
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgw,bwkd->bkgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, 1, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention block (projections + rope + norm variants)
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ArchConfig, dtype) -> Params:
    d, H, KV = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    Dh = cfg.resolved_head_dim()
    ks = jax.random.split(key, 5)
    p: Params = {
        "wq": dense_init(ks[0], (d, H * Dh), dtype),
        "wk": dense_init(ks[1], (d, KV * Dh), dtype),
        "wv": dense_init(ks[2], (d, KV * Dh), dtype),
        "wo": dense_init(ks[3], (H * Dh, d), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * Dh,), dtype)
        p["bk"] = jnp.zeros((KV * Dh,), dtype)
        p["bv"] = jnp.zeros((KV * Dh,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((Dh,), dtype)
        p["k_norm"] = jnp.ones((Dh,), dtype)
    return p


def _project_qkv(p: Params, x, cfg: ArchConfig):
    B, S, d = x.shape
    H, KV = cfg.n_heads, cfg.n_kv_heads
    Dh = cfg.resolved_head_dim()
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(B, S, H, Dh)
    k = k.reshape(B, S, KV, Dh)
    v = v.reshape(B, S, KV, Dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def attention_block(
    p: Params,
    x: jnp.ndarray,  # (B, S, d)
    cfg: ArchConfig,
    positions: jnp.ndarray,  # (B, S) or (B, 3, S) for mrope
    *,
    causal: bool = True,
    window: int = 0,
    differentiable: bool = True,
) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Full-sequence attention. Returns (out, (k, v)) for cache priming."""
    q, k, v = _project_qkv(p, x, cfg)
    if cfg.mrope:
        q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if cfg.use_flash_kernel and causal and window == 0 and differentiable is False:
        # Pallas flash kernel (forward-only paths: prefill/serving — the
        # kernel has no custom VJP; training keeps the jnp chunked path)
        from repro.kernels.ops import flash_mha

        out = flash_mha(q, k, v, causal=True)
    else:
        out = chunked_attention(q, k, v, causal=causal, window=window,
                                differentiable=differentiable,
                                q_chunk=cfg.attn_chunk, k_chunk=cfg.attn_chunk,
                                unroll_kv=cfg.unroll_attn)
    B, S, _, _ = q.shape
    out = out.reshape(B, S, -1) @ p["wo"]
    return out, (k, v)


def attention_decode_block(
    p: Params,
    x: jnp.ndarray,  # (B, 1, d)
    cfg: ArchConfig,
    pos: jnp.ndarray,  # (B,)
    cache: Dict[str, jnp.ndarray],
    *,
    window: int = 0,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """One decode step against a ring-buffer KV cache.

    cache = {"k": (B,W,KV,D), "v": (B,W,KV,D), "pos": (B,W) int32}
    """
    B = x.shape[0]
    positions = pos[:, None]  # (B, 1)
    if cfg.mrope:
        pos3 = jnp.broadcast_to(positions[:, None, :], (B, 3, 1))
        q, k, v = _project_qkv(p, x, cfg)
        q = apply_mrope(q, pos3, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, pos3, cfg.rope_theta, cfg.mrope_sections)
    else:
        q, k, v = _project_qkv(p, x, cfg)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    W = cache["k"].shape[1]
    slot = (pos % W).astype(jnp.int32)  # (B,)
    bidx = jnp.arange(B)
    k_cache = cache["k"].at[bidx, slot].set(k[:, 0])
    v_cache = cache["v"].at[bidx, slot].set(v[:, 0])
    pos_cache = cache["pos"].at[bidx, slot].set(pos.astype(jnp.int32))
    out = decode_attention(q, k_cache, v_cache, pos_cache, pos, window=window)
    out = out.reshape(B, 1, -1) @ p["wo"]
    return out, {"k": k_cache, "v": v_cache, "pos": pos_cache}


# ---------------------------------------------------------------------------
# MLP (SwiGLU)
# ---------------------------------------------------------------------------


def init_mlp(key, d_model: int, d_ff: int, dtype) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], (d_model, d_ff), dtype),
        "w_up": dense_init(ks[1], (d_model, d_ff), dtype),
        "w_down": dense_init(ks[2], (d_ff, d_model), dtype),
    }


def mlp_block(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]


# ---------------------------------------------------------------------------
# MoE: top-k router + sort-based capacity dispatch (expert parallel)
# ---------------------------------------------------------------------------


def init_moe(key, cfg: ArchConfig, dtype) -> Params:
    d, E, F = cfg.d_model, cfg.n_experts, cfg.moe_d_ff or cfg.d_ff
    ks = jax.random.split(key, 4)
    p: Params = {
        # router stays high-precision (precision-sensitive; see DESIGN §5)
        "router": dense_init(ks[0], (d, E), jnp.float32),
        "w_gate": dense_init(ks[1], (E, d, F), dtype),
        "w_up": dense_init(ks[2], (E, d, F), dtype),
        "w_down": dense_init(ks[3], (E, F, d), dtype),
    }
    if cfg.dense_residual:
        p["dense_mlp"] = init_mlp(jax.random.fold_in(key, 7), d, cfg.d_ff, dtype)
    return p


def _route_local(xf, router, E: int, K: int, capacity: int):
    """Local top-K routing + rank-within-expert. xf: (T, d).

    Returns (gate_vals (T,K), safe_expert (TK,), safe_rank (TK,),
    keep (TK,), aux).
    """
    T = xf.shape[0]
    logits = xf.astype(jnp.float32) @ router  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)
    # aux load-balance loss (Switch): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(expert_ids[:, 0], E, dtype=jnp.float32),
                  axis=0)
    aux = E * jnp.sum(me * ce)

    flat_expert = expert_ids.reshape(-1)  # (TK,)
    order = jnp.argsort(flat_expert, stable=True)
    sorted_expert = flat_expert[order]
    first = jnp.searchsorted(sorted_expert, jnp.arange(E), side="left")
    rank_sorted = jnp.arange(T * K) - first[sorted_expert]
    rank = jnp.zeros_like(rank_sorted).at[order].set(rank_sorted)
    keep = rank < capacity
    safe_expert = jnp.where(keep, flat_expert, 0)
    safe_rank = jnp.where(keep, rank, 0)
    return gate_vals, safe_expert, safe_rank, keep, aux


def _moe_math_local(xf, p, E: int, K: int, cap_factor: float):
    """Single-device MoE: route -> (E, C, d) buffer -> expert einsum ->
    gather+reshape combine (no scatter in the combine)."""
    T, d = xf.shape
    C = max(1, int(T * K / E * cap_factor))
    gate_vals, safe_expert, safe_rank, keep, aux = _route_local(
        xf, p["router"], E, K, C)
    tok_of = jnp.arange(T * K) // K
    buf = jnp.zeros((E, C, d), xf.dtype)
    contrib = jnp.where(keep[:, None], xf[tok_of], 0)
    buf = buf.at[safe_expert, safe_rank].add(contrib.astype(xf.dtype))
    h = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u, p["w_down"])
    gathered = y[safe_expert, safe_rank]  # (TK, d)
    weighted = gathered.astype(jnp.float32) * \
        jnp.where(keep, gate_vals.reshape(-1), 0.0)[:, None]
    out = weighted.reshape(T, K, d).sum(axis=1)
    return out.astype(xf.dtype), aux


def _mesh_info():
    mesh = get_abstract_mesh()
    if mesh.empty:
        return None
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    dp_axes = tuple(a for a in ("pod", "data") if a in sizes)
    dp = 1
    for a in dp_axes:
        dp *= sizes[a]
    return {"sizes": sizes, "dp_axes": dp_axes, "dp": dp,
            "mp": sizes.get("model", 1)}


def moe_uses_shard_map(info, E: int, K: int, T: int) -> bool:
    """Route MoE through the expert-parallel all-to-all path?

    Requires a model axis to parallelise over, divisible experts/tokens,
    and enough routed work per device to amortise gathering the local
    expert weights: decode steps route T_loc*K << E pairs, where the
    GSPMD fallback (weights stay sharded) is cheaper — measured 1.9 s vs
    5.2 s collective on kimi decode_32k (EXPERIMENTS.md §Perf iter 6).
    """
    return (
        info is not None and info["mp"] > 1 and E % info["mp"] == 0
        and T % info["dp"] == 0
        and (T // info["dp"]) * K >= E
    )


def moe_block(
    p: Params,
    x: jnp.ndarray,  # (B, S, d)
    cfg: ArchConfig,
    *,
    capacity_factor: float = 1.25,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (out, aux_load_balance_loss).

    Distributed path (§Perf iter 4/5): explicit expert parallelism via
    ``shard_map`` — tokens stay on their data shard, routing/sort/scatter
    are device-local, and the dispatch/return transport is a pair of
    ``all_to_all`` collectives over the ``model`` axis (bytes ≈
    2·T_loc·K·cf·d per device per layer). Letting GSPMD partition a shared
    dispatch buffer instead was measured at 9.9 TB (single (E,C,d) buffer,
    all-reduced over data) and 89 TB (grouped (G,E,C,d) buffer, scatter
    replication) of per-step collective traffic on kimi-k2 train_4k.

    Falls back to the purely local math on a single device / indivisible
    shapes. Token overflow beyond each expert's per-source capacity is
    dropped (GShard-style; the aux loss pushes the router toward balance).
    """
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.experts_per_token
    T = B * S
    info = _mesh_info()
    use_shard_map = moe_uses_shard_map(info, E, K, T)

    if not use_shard_map:
        out, aux = _moe_math_local(x.reshape(T, d), p, E, K, capacity_factor)
        out = out.reshape(B, S, d)
        if cfg.dense_residual:
            out = out + mlp_block(p["dense_mlp"], x)
        return out, aux

    M = info["mp"]
    dp_axes = info["dp_axes"]
    E_loc = E // M
    T_loc = T // info["dp"]
    C = max(1, int(T_loc * K / E * capacity_factor))

    def inner(router, w_gate, w_up, w_down, xf):
        # local views: xf (1..,T_loc,d); weights are this device's expert
        # slice (E_loc, d, F); router replicated.
        xf = xf.reshape(T_loc, d)
        gate_vals, safe_expert, safe_rank, keep, aux = _route_local(
            xf, router, E, K, C)
        aux = jax.lax.pmean(aux, dp_axes + ("model",))
        tok_of = jnp.arange(T_loc * K) // K
        # device-local dispatch buffer, grouped by target model-device
        send = jnp.zeros((E, C, d), xf.dtype)
        contrib = jnp.where(keep[:, None], xf[tok_of], 0)
        send = send.at[safe_expert, safe_rank].add(contrib.astype(xf.dtype))
        send = send.reshape(M, E_loc, C, d)
        # all-to-all over the model axis: row m -> model-device m;
        # received rows indexed by source device. The expert einsums keep
        # the source-device axis as a batch dim — no transposes (each
        # transpose materialised a full dispatch buffer; §Perf iter 5b).
        recv = jax.lax.all_to_all(send, "model", split_axis=0, concat_axis=0,
                                  tiled=True)  # (M, E_loc, C, d)
        h = jnp.einsum("mecd,edf->mecf", recv, w_gate)
        u = jnp.einsum("mecd,edf->mecf", recv, w_up)
        y = jnp.einsum("mecf,efd->mecd", jax.nn.silu(h) * u, w_down)
        got = jax.lax.all_to_all(y, "model", split_axis=0, concat_axis=0,
                                 tiled=True).reshape(E, C, d)
        gathered = got[safe_expert, safe_rank]  # (T_loc*K, d), stays bf16
        gate = jnp.where(keep, gate_vals.reshape(-1), 0.0)
        weighted = gathered * gate[:, None].astype(gathered.dtype)
        out = weighted.reshape(T_loc, K, d).sum(axis=1).astype(xf.dtype)
        return out, aux

    mesh = get_abstract_mesh()
    from jax.experimental.shard_map import shard_map

    dp_entry = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    out, aux = shard_map(
        inner, mesh=mesh,
        in_specs=(P(None, None),            # router (replicated)
                  P("model", None, None),   # w_gate: expert slice
                  P("model", None, None),   # w_up
                  P("model", None, None),   # w_down
                  P(dp_entry, None)),       # tokens: (T, d) over dp
        out_specs=(P(dp_entry, None), P()),
        check_rep=False,
    )(p["router"], p["w_gate"], p["w_up"], p["w_down"], x.reshape(T, d))
    out = out.reshape(B, S, d)

    if cfg.dense_residual:
        out = out + mlp_block(p["dense_mlp"], x)
    return out, aux
