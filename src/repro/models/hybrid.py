"""Zamba2-style hybrid: Mamba-2 backbone + one *shared* attention block.

The Zamba design (arXiv:2411.15242) interleaves a weight-shared attention
block every ``attn_every`` mamba layers (the shared block reads the
concatenation of the current hidden state and the original embedding).
We implement the two-level structure as nested scans:

    outer scan over segments (n_layers // attn_every of them)
      inner scan over that segment's mamba2 layers (stacked params)
      then the shared attention block (same weights each application)

which keeps HLO compact for the 54-layer production config.

Simplifications vs the released checkpoints (noted per DESIGN.md §9):
single shared block (Zamba2 alternates two) and no per-invocation LoRA.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import ssm as S
from repro.util import constrain, dtype_of

Params = Dict[str, Any]


def _segments(cfg: ArchConfig) -> Tuple[int, int]:
    every = cfg.attn_every or cfg.n_layers
    n_seg = max(1, cfg.n_layers // every)
    return n_seg, every


def init_hybrid(key, cfg: ArchConfig) -> Params:
    dtype = dtype_of(cfg.param_dtype)
    n_seg, every = _segments(cfg)
    k_embed, k_m, k_a, k_head = jax.random.split(key, 4)
    layer_keys = jax.random.split(k_m, n_seg * every)

    def one(k):
        return {"norm": jnp.ones((cfg.d_model,), dtype),
                "mamba": S.init_mamba2(k, cfg, dtype)}

    stacked = jax.vmap(one)(layer_keys)
    # reshape leading axis to (n_seg, every)
    stacked = jax.tree.map(
        lambda a: a.reshape((n_seg, every) + a.shape[1:]), stacked
    )
    shared = {
        "attn_norm": jnp.ones((cfg.d_model,), dtype),
        "mlp_norm": jnp.ones((cfg.d_model,), dtype),
        "attn": L.init_attention(jax.random.fold_in(k_a, 0), cfg, dtype),
        "mlp": L.init_mlp(jax.random.fold_in(k_a, 1), cfg.d_model, cfg.d_ff, dtype),
        # projection for the concat([hidden, embedding]) input of the shared block
        "in_proj": L.dense_init(jax.random.fold_in(k_a, 2),
                                (2 * cfg.d_model, cfg.d_model), dtype),
    }
    return {
        "embed": L.embed_init(k_embed, (cfg.vocab_size, cfg.d_model), dtype),
        "segments": stacked,
        "shared": shared,
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "lm_head": L.dense_init(k_head, (cfg.d_model, cfg.vocab_size), dtype),
    }


def _shared_attn(shared: Params, x, x0, cfg: ArchConfig, positions,
                 differentiable: bool = True):
    """The weight-shared attention block. x0 = original embeddings."""
    inp = jnp.concatenate([x, x0], axis=-1) @ shared["in_proj"]
    h, kv = L.attention_block(
        shared["attn"], L.rms_norm(inp, shared["attn_norm"], cfg.norm_eps),
        cfg, positions, causal=True, differentiable=differentiable,
    )
    x = x + h
    x = x + L.mlp_block(shared["mlp"], L.rms_norm(x, shared["mlp_norm"], cfg.norm_eps))
    return x, kv


def _forward(params: Params, tokens, cfg: ArchConfig, collect_state: bool,
             differentiable: bool = True):
    x = params["embed"][tokens].astype(dtype_of(cfg.compute_dtype))
    x = constrain(x, P(("pod", "data"), None, None))
    B, T = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    x0 = x

    def inner(xc, layer_p):
        xn = L.rms_norm(xc, layer_p["norm"], cfg.norm_eps)
        out, state = S.mamba2_block(layer_p["mamba"], xn, cfg,
                                    return_state=collect_state)
        return xc + out, state

    inner_fn = jax.checkpoint(inner) if cfg.remat and not collect_state else inner

    n_seg, every = _segments(cfg)

    def outer(xc, seg_p):
        if cfg.unroll_layers:
            states_l = []
            for i in range(every):
                layer_p = jax.tree.map(lambda a: a[i], seg_p)
                xc, st = inner_fn(xc, layer_p)
                states_l.append(st)
            states = (jax.tree.map(lambda *xs: jnp.stack(xs), *states_l)
                      if collect_state else None)
        else:
            xc, states = jax.lax.scan(inner_fn, xc, seg_p)
        xc, kv = _shared_attn(params["shared"], xc, x0, cfg, positions,
                              differentiable=differentiable)
        emit = (states, kv) if collect_state else None
        return xc, emit

    if cfg.unroll_layers:
        emits = []
        for s in range(n_seg):
            seg_p = jax.tree.map(lambda a: a[s], params["segments"])
            x, em = outer(x, seg_p)
            emits.append(em)
        collected = (jax.tree.map(lambda *xs: jnp.stack(xs), *emits)
                     if collect_state else None)
    else:
        x, collected = jax.lax.scan(outer, x, params["segments"])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, collected


def hybrid_prefill(params: Params, batch, cfg: ArchConfig):
    """Full forward collecting SSM final states + shared-attn K/V."""
    tokens = batch["tokens"]
    B, T = tokens.shape
    x, collected = _forward(params, tokens, cfg, collect_state=True,
                            differentiable=False)
    states, kvs = collected
    logits = (x[:, -1] @ params["lm_head"]).astype(jnp.float32)
    k_stack, v_stack = kvs  # (n_seg, B, T, KV, Dh)
    n_seg = k_stack.shape[0]
    cache = {
        "ssm_h": states["h"],
        "ssm_conv": states["conv"],
        "k": k_stack,
        "v": v_stack,
        "pos": jnp.broadcast_to(
            jnp.arange(T, dtype=jnp.int32)[None, None], (n_seg, B, T)),
    }
    return logits, cache


def hybrid_loss(params: Params, batch, cfg: ArchConfig):
    x, _ = _forward(params, batch["tokens"], cfg, collect_state=False)
    h = x[:, :-1]
    targets = batch["tokens"][:, 1:]
    logits = (h @ params["lm_head"]).astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    loss = jnp.mean(logz - gold)
    return loss, {"ce": loss, "aux": jnp.zeros(())}


def init_hybrid_cache(cfg: ArchConfig, B: int, cache_len: int) -> Params:
    dt = dtype_of(cfg.param_dtype)
    n_seg, every = _segments(cfg)
    di = cfg.resolved_d_inner()
    H = cfg.resolved_ssm_heads()
    N = cfg.ssm_state
    Pd = di // H
    K = cfg.ssm_conv
    conv_dim = di + 2 * N
    KV, Dh = cfg.n_kv_heads, cfg.resolved_head_dim()
    return {
        "ssm_h": jnp.zeros((n_seg, every, B, H, Pd, N), jnp.float32),
        "ssm_conv": jnp.zeros((n_seg, every, B, K - 1, conv_dim), dt),
        "k": jnp.zeros((n_seg, B, cache_len, KV, Dh), dt),
        "v": jnp.zeros((n_seg, B, cache_len, KV, Dh), dt),
        "pos": jnp.full((n_seg, B, cache_len), -1, jnp.int32),
    }


def hybrid_decode_step(params: Params, cache, batch, cfg: ArchConfig,
                       *, window: int = 0):
    x = params["embed"][batch["tokens"]].astype(dtype_of(cfg.compute_dtype))
    pos = batch["pos"]
    x0 = x[:, 0]

    def inner(xc, scanned):
        layer_p, h_state, conv_state = scanned
        xn = L.rms_norm(xc, layer_p["norm"], cfg.norm_eps)
        out, new_state = S.mamba2_decode(
            layer_p["mamba"], xn, cfg, {"h": h_state, "conv": conv_state}
        )
        return xc + out, (new_state["h"], new_state["conv"])

    def outer(xc, scanned):
        seg_p, seg_h, seg_conv, k_c, v_c, pos_c = scanned
        if cfg.unroll_layers:
            _, every = _segments(cfg)
            ems = []
            for i in range(every):
                sl = jax.tree.map(lambda a: a[i], (seg_p, seg_h, seg_conv))
                xc, em = inner(xc, sl)
                ems.append(em)
            new_h, new_conv = jax.tree.map(lambda *xs: jnp.stack(xs), *ems)
        else:
            xc, (new_h, new_conv) = jax.lax.scan(inner, xc, (seg_p, seg_h, seg_conv))
        inp = jnp.concatenate([xc, x0[:, None]], axis=-1) @ params["shared"]["in_proj"]
        h, new_kv = L.attention_decode_block(
            params["shared"]["attn"],
            L.rms_norm(inp, params["shared"]["attn_norm"], cfg.norm_eps),
            cfg, pos, {"k": k_c, "v": v_c, "pos": pos_c}, window=window,
        )
        xc = xc + h
        xc = xc + L.mlp_block(
            params["shared"]["mlp"],
            L.rms_norm(xc, params["shared"]["mlp_norm"], cfg.norm_eps),
        )
        return xc, (new_h, new_conv, new_kv["k"], new_kv["v"], new_kv["pos"])

    scanned_args = (params["segments"], cache["ssm_h"], cache["ssm_conv"],
                    cache["k"], cache["v"], cache["pos"])
    if cfg.unroll_layers:
        n_seg, _ = _segments(cfg)
        emits = []
        for s in range(n_seg):
            sl = jax.tree.map(lambda a: a[s], scanned_args)
            x, em = outer(x, sl)
            emits.append(em)
        new_h, new_conv, k_n, v_n, pos_n = jax.tree.map(
            lambda *xs: jnp.stack(xs), *emits)
    else:
        x, (new_h, new_conv, k_n, v_n, pos_n) = jax.lax.scan(
            outer, x, scanned_args)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (x[:, 0] @ params["lm_head"]).astype(jnp.float32)
    new_cache = {"ssm_h": new_h, "ssm_conv": new_conv, "k": k_n, "v": v_n,
                 "pos": pos_n}
    return logits, new_cache
