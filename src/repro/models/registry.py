"""Uniform model API over the zoo.

``build_model(cfg)`` returns a ``Model`` with:
- ``init(key)``                       -> params
- ``loss(params, batch)``             -> (scalar, metrics)   [train]
- ``prefill(params, batch)``          -> (logits, cache)     [attention archs]
- ``init_cache(B, cache_len)``        -> cache pytree
- ``decode(params, cache, batch)``    -> (logits, cache)
- ``input_spec(shape)``               -> dict of ShapeDtypeStructs (launch)
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, InputShape
from repro.models import deepspeech2 as DS2
from repro.models import hybrid as HY
from repro.models import transformer as TF
from repro.models import whisper as WH

# decode beyond this cache length switches to the sliding-window ring buffer
FULL_CACHE_MAX = 32_768


@dataclasses.dataclass
class Model:
    cfg: ArchConfig
    init: Callable
    loss: Callable
    init_cache: Callable
    decode: Callable
    prefill: Optional[Callable] = None

    def cache_len_for(self, seq_len: int) -> int:
        if self.cfg.family in ("ssm",):
            return 0
        if seq_len > FULL_CACHE_MAX:
            return self.cfg.window
        return seq_len

    def decode_window_for(self, seq_len: int) -> int:
        if seq_len > FULL_CACHE_MAX:
            return self.cfg.window
        return 0

    def grow_cache(self, cache, new_len: int):
        """Pad attention K/V/pos slots (e.g. after prefill, before decode).

        SSM caches are fixed-size state: returned unchanged.
        """
        import jax.numpy as jnp

        def fit(name, cur):
            if name in ("k", "v"):
                axis = cur.ndim - 3
            elif name == "pos":
                axis = cur.ndim - 1
            else:
                return cur
            pad_n = new_len - cur.shape[axis]
            if pad_n <= 0:
                return cur
            pad = [(0, 0)] * cur.ndim
            pad[axis] = (0, pad_n)
            return jnp.pad(cur, pad, constant_values=-1 if name == "pos" else 0)

        return {k: fit(k, v) for k, v in cache.items()}

    def input_spec(self, shape: InputShape) -> Dict[str, jax.ShapeDtypeStruct]:
        """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
        cfg = self.cfg
        B = shape.global_batch
        S = shape.seq_len
        tok = jnp.int32
        if shape.kind == "train":
            if cfg.family == "audio":
                # stub frontend delivers embedded frames; tokens are targets
                return {
                    "frames": jax.ShapeDtypeStruct(
                        (B, cfg.encoder_seq, cfg.frontend_dim), jnp.bfloat16),
                    "tokens": jax.ShapeDtypeStruct((B, S), tok),
                }
            if cfg.family == "ds2":
                return {
                    "frames": jax.ShapeDtypeStruct(
                        (B, S, cfg.frontend_dim), jnp.float32),
                    "labels": jax.ShapeDtypeStruct((B, S // 8), tok),
                    "frame_len": jax.ShapeDtypeStruct((B,), tok),
                    "label_len": jax.ShapeDtypeStruct((B,), tok),
                }
            spec = {"tokens": jax.ShapeDtypeStruct((B, S), tok)}
            if cfg.family == "vlm":
                # stub vision frontend: 256 patch embeddings prepended
                spec["patches"] = jax.ShapeDtypeStruct(
                    (B, 256, cfg.frontend_dim), jnp.bfloat16)
                spec["tokens"] = jax.ShapeDtypeStruct((B, S - 256), tok)
            return spec
        if shape.kind == "prefill":
            if cfg.family == "audio":
                return {
                    "frames": jax.ShapeDtypeStruct(
                        (B, cfg.encoder_seq, cfg.frontend_dim), jnp.bfloat16),
                    "tokens": jax.ShapeDtypeStruct((B, S), tok),
                }
            spec = {"tokens": jax.ShapeDtypeStruct((B, S), tok)}
            if cfg.family == "vlm":
                spec["patches"] = jax.ShapeDtypeStruct(
                    (B, 256, cfg.frontend_dim), jnp.bfloat16)
                spec["tokens"] = jax.ShapeDtypeStruct((B, S - 256), tok)
            return spec
        # decode: one new token against a cache of length seq_len
        return {
            "tokens": jax.ShapeDtypeStruct((B, 1), tok),
            "pos": jax.ShapeDtypeStruct((B,), tok),
        }


def build_model(cfg: ArchConfig) -> Model:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm", "ssm"):
        return Model(
            cfg=cfg,
            init=lambda key: TF.init_lm(key, cfg),
            loss=lambda p, b: TF.lm_loss(p, b, cfg),
            init_cache=lambda B, n: TF.init_decode_cache(cfg, B, n),
            decode=lambda p, c, b, window=0: TF.decode_step(
                p, c, b, cfg, window=window),
            prefill=lambda p, b: TF.prefill(p, b, cfg),
        )
    if fam == "hybrid":
        return Model(
            cfg=cfg,
            init=lambda key: HY.init_hybrid(key, cfg),
            loss=lambda p, b: HY.hybrid_loss(p, b, cfg),
            init_cache=lambda B, n: HY.init_hybrid_cache(cfg, B, n),
            decode=lambda p, c, b, window=0: HY.hybrid_decode_step(
                p, c, b, cfg, window=window),
            prefill=lambda p, b: HY.hybrid_prefill(p, b, cfg),
        )
    if fam == "audio":
        return Model(
            cfg=cfg,
            init=lambda key: WH.init_whisper(key, cfg),
            loss=lambda p, b: WH.whisper_loss(p, b, cfg),
            init_cache=lambda B, n: WH.init_whisper_cache(cfg, B, n),
            decode=lambda p, c, b, window=0: WH.whisper_decode_step(
                p, c, b, cfg, window=window),
            prefill=lambda p, b: WH.whisper_prefill(p, b, cfg),
        )
    if fam == "ds2":
        return Model(
            cfg=cfg,
            init=lambda key: DS2.init_ds2(key, cfg),
            loss=lambda p, b: DS2.ds2_loss(p, b, cfg),
            init_cache=lambda B, n: {},
            decode=lambda p, c, b, window=0: (_ for _ in ()).throw(
                NotImplementedError("ds2 is CTC/non-autoregressive")),
        )
    raise ValueError(f"unknown family {fam!r}")
