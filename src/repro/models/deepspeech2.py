"""DeepSpeech2-style ASR model (the paper's FL experiment model, §IV-A).

conv-over-time frontend (2 strided depth layers) -> bidirectional GRU stack
-> framewise projection -> CTC loss. Sized for 100-client CPU simulation.
The synthetic "mel" features come from repro.data.voice.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import dense_init, layer_norm
from repro.util import dtype_of

Params = Dict[str, Any]

BLANK = 0  # CTC blank id (vocab id 0 reserved)


# ---------------------------------------------------------------------------
# GRU
# ---------------------------------------------------------------------------


def init_gru(key, d_in: int, d_hidden: int, dtype) -> Params:
    ks = jax.random.split(key, 2)
    return {
        "w_x": dense_init(ks[0], (d_in, 3 * d_hidden), dtype),
        "w_h": dense_init(ks[1], (d_hidden, 3 * d_hidden), dtype),
        "b": jnp.zeros((3 * d_hidden,), dtype),
    }


def gru_scan(p: Params, x: jnp.ndarray, reverse: bool = False) -> jnp.ndarray:
    """x: (B, T, d_in) -> (B, T, d_hidden)."""
    B, T, _ = x.shape
    H = p["w_h"].shape[0]
    xz = x @ p["w_x"] + p["b"]  # precompute input projections (B, T, 3H)

    def step(h, xz_t):
        rzn_h = h @ p["w_h"]
        r = jax.nn.sigmoid(xz_t[..., :H] + rzn_h[..., :H])
        z = jax.nn.sigmoid(xz_t[..., H : 2 * H] + rzn_h[..., H : 2 * H])
        n = jnp.tanh(xz_t[..., 2 * H :] + r * rzn_h[..., 2 * H :])
        h_new = (1 - z) * n + z * h
        return h_new, h_new

    h0 = jnp.zeros((B, H), x.dtype)
    _, hs = jax.lax.scan(step, h0, xz.swapaxes(0, 1), reverse=reverse)
    return hs.swapaxes(0, 1)


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------


def init_ds2(key, cfg: ArchConfig) -> Params:
    dtype = dtype_of(cfg.param_dtype)
    F, H, V = cfg.frontend_dim, cfg.d_model, cfg.vocab_size
    ks = jax.random.split(key, 4 + 2 * cfg.n_layers)
    p: Params = {
        "conv1_w": dense_init(ks[0], (11, F, H), dtype),   # (taps, in, out)
        "conv1_b": jnp.zeros((H,), dtype),
        "conv2_w": dense_init(ks[1], (11, H, H), dtype),
        "conv2_b": jnp.zeros((H,), dtype),
        "ln1_w": jnp.ones((H,), dtype), "ln1_b": jnp.zeros((H,), dtype),
        "ln2_w": jnp.ones((H,), dtype), "ln2_b": jnp.zeros((H,), dtype),
        "out_w": dense_init(ks[2], (2 * H, V), dtype),
        "out_b": jnp.zeros((V,), dtype),
        "gru": [],
    }
    grus = []
    d_in = H
    for i in range(cfg.n_layers):
        grus.append({
            "fwd": init_gru(ks[4 + 2 * i], d_in, H, dtype),
            "bwd": init_gru(ks[5 + 2 * i], d_in, H, dtype),
            "ln_w": jnp.ones((2 * H,), dtype), "ln_b": jnp.zeros((2 * H,), dtype),
        })
        d_in = 2 * H
    p["gru"] = grus
    return p


def _conv_time(x, w, b, stride: int):
    """1-D conv over time. x: (B, T, Cin); w: (K, Cin, Cout)."""
    out = jax.lax.conv_general_dilated(
        x, w, window_strides=(stride,), padding="SAME",
        dimension_numbers=("NTC", "TIO", "NTC"),
    )
    return out + b


def ds2_logits(params: Params, frames: jnp.ndarray, cfg: ArchConfig):
    """frames: (B, T, F) -> log-probs (B, T//4, V)."""
    x = frames.astype(dtype_of(cfg.compute_dtype))
    x = jax.nn.relu(layer_norm(_conv_time(x, params["conv1_w"], params["conv1_b"], 2),
                               params["ln1_w"], params["ln1_b"]))
    x = jax.nn.relu(layer_norm(_conv_time(x, params["conv2_w"], params["conv2_b"], 2),
                               params["ln2_w"], params["ln2_b"]))
    for g in params["gru"]:
        fwd = gru_scan(g["fwd"], x)
        bwd = gru_scan(g["bwd"], x, reverse=True)
        x = layer_norm(jnp.concatenate([fwd, bwd], axis=-1), g["ln_w"], g["ln_b"])
    logits = x @ params["out_w"] + params["out_b"]
    return jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)


# ---------------------------------------------------------------------------
# CTC loss (log-space forward algorithm)
# ---------------------------------------------------------------------------


def ctc_loss(
    log_probs: jnp.ndarray,  # (B, T, V) log-softmaxed
    labels: jnp.ndarray,  # (B, L) int32, 0 = padding (blank id is also 0)
    input_lengths: jnp.ndarray,  # (B,)
    label_lengths: jnp.ndarray,  # (B,)
) -> jnp.ndarray:
    """Mean negative log-likelihood over the batch."""
    B, T, V = log_probs.shape
    L = labels.shape[1]
    S = 2 * L + 1
    NEG = -1e30

    # extended label sequence: blank, l1, blank, l2, ..., blank
    ext = jnp.zeros((B, S), jnp.int32)
    ext = ext.at[:, 1::2].set(labels)
    # allow skip from s-2 when ext[s] != blank and ext[s] != ext[s-2]
    can_skip = jnp.zeros((B, S), bool)
    can_skip = can_skip.at[:, 2:].set(
        (ext[:, 2:] != BLANK) & (ext[:, 2:] != ext[:, :-2]))

    def get_lp(t):  # (B, S) label log-probs at frame t
        lp_t = log_probs[:, t]  # (B, V)
        return jnp.take_along_axis(lp_t, ext, axis=1)

    alpha0 = jnp.full((B, S), NEG)
    alpha0 = alpha0.at[:, 0].set(log_probs[:, 0, BLANK])
    alpha0 = alpha0.at[:, 1].set(
        jnp.where(label_lengths > 0, get_lp(0)[:, 1], NEG))

    def step(alpha, t):
        stay = alpha
        prev1 = jnp.concatenate([jnp.full((B, 1), NEG), alpha[:, :-1]], axis=1)
        prev2 = jnp.concatenate([jnp.full((B, 2), NEG), alpha[:, :-2]], axis=1)
        prev2 = jnp.where(can_skip, prev2, NEG)
        merged = jnp.logaddexp(jnp.logaddexp(stay, prev1), prev2)
        alpha_new = merged + get_lp(t)
        # frames beyond input length keep alpha frozen
        alpha_new = jnp.where((t < input_lengths)[:, None], alpha_new, alpha)
        return alpha_new, None

    alpha, _ = jax.lax.scan(step, alpha0, jnp.arange(1, T))

    # final: sum of last blank and last label positions
    last = 2 * label_lengths  # index of final blank
    idx_b = jnp.arange(B)
    ll = jnp.logaddexp(
        alpha[idx_b, last],
        jnp.where(label_lengths > 0, alpha[idx_b, jnp.maximum(last - 1, 0)], NEG),
    )
    return -jnp.mean(ll / jnp.maximum(label_lengths.astype(jnp.float32), 1.0))


def ds2_loss(params: Params, batch: Dict[str, jnp.ndarray], cfg: ArchConfig):
    """batch: frames (B,T,F), labels (B,L), frame_len (B,), label_len (B,)."""
    lp = ds2_logits(params, batch["frames"], cfg)
    in_len = jnp.minimum(batch["frame_len"] // 4, lp.shape[1])
    loss = ctc_loss(lp, batch["labels"], in_len, batch["label_len"])
    return loss, {"ce": loss, "aux": jnp.zeros(())}


def ds2_greedy_decode(params: Params, frames, cfg: ArchConfig) -> jnp.ndarray:
    """Greedy CTC decode -> (B, T') token ids with blanks/repeats collapsed
    marked as 0."""
    lp = ds2_logits(params, frames, cfg)
    ids = jnp.argmax(lp, axis=-1)  # (B, T')
    prev = jnp.concatenate([jnp.full_like(ids[:, :1], -1), ids[:, :-1]], axis=1)
    keep = (ids != BLANK) & (ids != prev)
    return jnp.where(keep, ids, 0)
