"""Whisper-tiny style encoder–decoder (backbone only; conv frontend stubbed).

The mel-spectrogram + conv feature extractor is a stub per the assignment
carve-out: callers provide precomputed frame embeddings (B, T_enc, d_model).
Positions are sinusoidal (computed on the fly, so a 500k-decode never
materialises a position table). MLPs are SwiGLU for uniformity with the
rest of the zoo (documented simplification vs whisper's GELU MLP).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.util import dtype_of

Params = Dict[str, Any]


def _run_stack(body, x, stacked: Params, n: int, unroll: bool):
    """scan-or-unroll over a stacked layer pytree; collects emitted pytrees."""
    if not unroll:
        return jax.lax.scan(body, x, stacked)
    emits = []
    for i in range(n):
        layer_p = jax.tree.map(lambda a: a[i], stacked)
        x, em = body(x, layer_p)
        emits.append(em)
    if emits and emits[0] is not None:
        return x, jax.tree.map(lambda *xs: jnp.stack(xs), *emits)
    return x, None


def sinusoid_pos(positions: jnp.ndarray, d: int) -> jnp.ndarray:
    """positions (...,) -> (..., d) sinusoidal embedding."""
    half = d // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _init_enc_layer(key, cfg: ArchConfig, dtype) -> Params:
    ks = jax.random.split(key, 2)
    return {
        "attn_norm": jnp.ones((cfg.d_model,), dtype),
        "mlp_norm": jnp.ones((cfg.d_model,), dtype),
        "attn": L.init_attention(ks[0], cfg, dtype),
        "mlp": L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype),
    }


def _init_dec_layer(key, cfg: ArchConfig, dtype) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "self_norm": jnp.ones((cfg.d_model,), dtype),
        "cross_norm": jnp.ones((cfg.d_model,), dtype),
        "mlp_norm": jnp.ones((cfg.d_model,), dtype),
        "self_attn": L.init_attention(ks[0], cfg, dtype),
        "cross_attn": L.init_attention(ks[1], cfg, dtype),
        "mlp": L.init_mlp(ks[2], cfg.d_model, cfg.d_ff, dtype),
    }


def init_whisper(key, cfg: ArchConfig) -> Params:
    dtype = dtype_of(cfg.param_dtype)
    k_e, k_enc, k_dec, k_tok = jax.random.split(key, 4)
    enc_keys = jax.random.split(k_enc, cfg.encoder_layers)
    dec_keys = jax.random.split(k_dec, cfg.n_layers)
    return {
        # frontend stub projector (frames already embedded at frontend_dim)
        "frame_proj": L.dense_init(k_e, (cfg.frontend_dim, cfg.d_model), dtype),
        "enc_layers": jax.vmap(lambda k: _init_enc_layer(k, cfg, dtype))(enc_keys),
        "enc_norm": jnp.ones((cfg.d_model,), dtype),
        "embed": L.embed_init(k_tok, (cfg.vocab_size, cfg.d_model), dtype),
        "dec_layers": jax.vmap(lambda k: _init_dec_layer(k, cfg, dtype))(dec_keys),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "lm_head": L.dense_init(jax.random.fold_in(k_tok, 1),
                                (cfg.d_model, cfg.vocab_size), dtype),
    }


def encode(params: Params, frames: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    """frames: (B, T_enc, frontend_dim) -> (B, T_enc, d)."""
    x = frames.astype(dtype_of(cfg.compute_dtype)) @ params["frame_proj"]
    B, T, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    x = x + sinusoid_pos(pos, cfg.d_model).astype(x.dtype)

    def body(xc, layer_p):
        h, _ = L.attention_block(
            layer_p["attn"], L.rms_norm(xc, layer_p["attn_norm"], cfg.norm_eps),
            cfg, pos, causal=False,
        )
        xc = xc + h
        xc = xc + L.mlp_block(
            layer_p["mlp"], L.rms_norm(xc, layer_p["mlp_norm"], cfg.norm_eps))
        return xc, None

    x, _ = _run_stack(body, x, params["enc_layers"], cfg.encoder_layers,
                      cfg.unroll_layers)
    return L.rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _cross_attend(layer_p: Params, x, enc_out, cfg: ArchConfig):
    """Cross-attention: queries from decoder x, K/V from encoder output."""
    B, S, d = x.shape
    xn = L.rms_norm(x, layer_p["cross_norm"], cfg.norm_eps)
    p = layer_p["cross_attn"]
    Dh = cfg.resolved_head_dim()
    q = (xn @ p["wq"]).reshape(B, S, cfg.n_heads, Dh)
    k = (enc_out @ p["wk"]).reshape(B, -1, cfg.n_kv_heads, Dh)
    v = (enc_out @ p["wv"]).reshape(B, -1, cfg.n_kv_heads, Dh)
    out = L.chunked_attention(q, k, v, causal=False,
                              q_chunk=cfg.attn_chunk, k_chunk=cfg.attn_chunk,
                              unroll_kv=cfg.unroll_attn)
    return x + out.reshape(B, S, -1) @ p["wo"]


def decoder_forward(params: Params, tokens, enc_out, cfg: ArchConfig,
                    differentiable: bool = True):
    x = params["embed"][tokens].astype(dtype_of(cfg.compute_dtype))
    B, S = tokens.shape
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = x + sinusoid_pos(pos, cfg.d_model).astype(x.dtype)

    def body(xc, layer_p):
        h, kv = L.attention_block(
            layer_p["self_attn"], L.rms_norm(xc, layer_p["self_norm"], cfg.norm_eps),
            cfg, pos, causal=True, differentiable=differentiable,
        )
        xc = xc + h
        xc = _cross_attend(layer_p, xc, enc_out, cfg)
        xc = xc + L.mlp_block(
            layer_p["mlp"], L.rms_norm(xc, layer_p["mlp_norm"], cfg.norm_eps))
        return xc, kv

    x, kvs = _run_stack(body, x, params["dec_layers"], cfg.n_layers,
                        cfg.unroll_layers)
    return L.rms_norm(x, params["final_norm"], cfg.norm_eps), kvs


def whisper_loss(params: Params, batch, cfg: ArchConfig):
    """batch: {"frames": (B,T_enc,F), "tokens": (B,S)}."""
    enc_out = encode(params, batch["frames"], cfg)
    x, _ = decoder_forward(params, batch["tokens"], enc_out, cfg)
    h = x[:, :-1]
    targets = batch["tokens"][:, 1:]
    logits = (h @ params["lm_head"]).astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    loss = jnp.mean(logz - gold)
    return loss, {"ce": loss, "aux": jnp.zeros(())}


def whisper_prefill(params: Params, batch, cfg: ArchConfig):
    """Encode frames + run the decoder over the prompt, priming the cache."""
    enc_out = encode(params, batch["frames"], cfg)
    tokens = batch["tokens"]
    B, S = tokens.shape
    x, kvs = decoder_forward(params, tokens, enc_out, cfg,
                             differentiable=False)
    logits = (x[:, -1] @ params["lm_head"]).astype(jnp.float32)
    k_stack, v_stack = kvs
    cache = {
        "k": k_stack,
        "v": v_stack,
        "pos": jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None, None],
            (cfg.n_layers, B, S)),
        "enc_out": enc_out,
    }
    return logits, cache


def init_whisper_cache(cfg: ArchConfig, B: int, cache_len: int) -> Params:
    dt = dtype_of(cfg.param_dtype)
    KV, Dh = cfg.n_kv_heads, cfg.resolved_head_dim()
    return {
        "k": jnp.zeros((cfg.n_layers, B, cache_len, KV, Dh), dt),
        "v": jnp.zeros((cfg.n_layers, B, cache_len, KV, Dh), dt),
        "pos": jnp.full((cfg.n_layers, B, cache_len), -1, jnp.int32),
        # encoder output is part of the decode state (computed at prefill)
        "enc_out": jnp.zeros((B, cfg.encoder_seq, cfg.d_model), dt),
    }


def whisper_decode_step(params: Params, cache, batch, cfg: ArchConfig,
                        *, window: int = 0):
    x = params["embed"][batch["tokens"]].astype(dtype_of(cfg.compute_dtype))
    pos = batch["pos"]
    x = x + sinusoid_pos(pos[:, None], cfg.d_model).astype(x.dtype)
    enc_out = cache["enc_out"]

    def body(xc, scanned):
        layer_p, k_c, v_c, pos_c = scanned
        h, new_kv = L.attention_decode_block(
            layer_p["self_attn"],
            L.rms_norm(xc, layer_p["self_norm"], cfg.norm_eps),
            cfg, pos, {"k": k_c, "v": v_c, "pos": pos_c}, window=window,
        )
        xc = xc + h
        xc = _cross_attend(layer_p, xc, enc_out, cfg)
        xc = xc + L.mlp_block(
            layer_p["mlp"], L.rms_norm(xc, layer_p["mlp_norm"], cfg.norm_eps))
        return xc, (new_kv["k"], new_kv["v"], new_kv["pos"])

    x, (k_n, v_n, pos_n) = _run_stack(
        body, x, (params["dec_layers"], cache["k"], cache["v"], cache["pos"]),
        cfg.n_layers, cfg.unroll_layers)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (x[:, 0] @ params["lm_head"]).astype(jnp.float32)
    return logits, {"k": k_n, "v": v_n, "pos": pos_n, "enc_out": enc_out}
