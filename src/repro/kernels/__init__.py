from repro.kernels.ops import (  # noqa: F401
    fake_quant, flash_mha, ota_aggregate, qmatmul, quantize_weights,
)
