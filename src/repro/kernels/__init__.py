from repro.kernels.ops import (  # noqa: F401
    fake_quant,
    flash_mha,
    ota_aggregate,
    ota_quantize_superpose,
    qmatmul,
    quantize_weights,
)
