"""Pallas TPU kernels: fused mixed-precision OTA data plane.

Two entry points share the (K, block) streaming grid and numerics:
``ota_fused_2d`` consumes f32 rows and quantizes in-pass (below);
``ota_packed_2d`` consumes pre-quantized bit-packed wire rows
(``core/packing.PackedRow``, DESIGN.md §6) and only unpacks + dequantizes
— for a 4-bit cohort its HBM read is 1/8 of the f32 matrix.

One pass over the flat ``(K, M)`` client-update matrix does the whole
per-round hot loop that ``core/ota.py`` used to run as three materialized
stages per client (quantize -> dequantize -> weighted add):

    for each (K, BLOCK_COLS) tile:
        u_k   = dither(seed, client, position)            (computed, not read)
        q_k   = clip(floor(x_k / s_k) + (u_k < frac), -qmax_k, qmax_k)
        dq_k  = q_k * s_k                (or x_k when qmax_k == 0: fp32 client)
        acc   = sum_k w_k * dq_k         (VPU K-step FMA)
        out  += acc;  sumsq += |acc|^2   (running scalar for the AWGN power)

Per-client scalars — quant scale ``s_k``, symmetric range ``qmax_k``, and
FedAvg/channel weight ``w_k`` — ride along as (K, 1) blocks resident for
every grid step; the parameter axis streams through VMEM, so HBM traffic
is one read of x plus one write of the aggregate. The kernel is
bits-agnostic: precision enters only through the qmax/scale arrays, so
one compiled program serves every precision mix.

Stochastic-rounding dither: a counter-based positional hash
(``sr_dither``: murmur3 finalizer over seed/client/position) generated
*inside* the kernel. The dither needs avalanche, not cryptographic
strength — on CPU a threefry draw of the same (K, M) uniforms costs ~3x
the entire fused math, and as a kernel input it would double the HBM read
traffic. Being a pure function of (seed, client, position), the jnp
oracle (``ref.ota_fused_ref``) and the per-tree reference
(``core/ota.ota_aggregate_pertree``) reproduce the exact same numbers.

The receiver AWGN rides the epilogue in ``core/ota.py`` rather than this
kernel: its std is defined by the *global* aggregate norm (SNR relative to
the received signal), which only exists after the reduction. The kernel
therefore emits the blockwise sum-of-squares as a second (1, 1) output —
accumulated across the sequential TPU grid — so the O(M) noise axpy is the
only work left outside the single O(K*M) pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_COLS = 2048
LANES = 128

_GOLDEN = 0x9E3779B9  # Weyl increment decorrelating client rows


def sr_dither(seed, rows, pos) -> jnp.ndarray:
    """Positional uniform dither u in [0, 1) for stochastic rounding.

    murmur3 finalizer (SplitMix-style counter hash) of
    ``pos ^ (seed + GOLDEN * row)`` — ~6 elementwise int ops per element.
    seed/rows/pos: uint32 arrays (broadcastable). 24-bit mantissa-exact
    output, strictly below 1 so integer inputs never round away.
    """
    seed = seed.astype(jnp.uint32)
    rows = rows.astype(jnp.uint32)
    pos = pos.astype(jnp.uint32)
    h = pos ^ (seed + jnp.uint32(_GOLDEN) * rows)
    h = h ^ (h >> jnp.uint32(16))
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> jnp.uint32(13))
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> jnp.uint32(16))
    return (h >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))


def _fused_kernel(seed_ref, scale_ref, qmax_ref, w_ref, x_ref, o_ref, ss_ref):
    i = pl.program_id(0)
    K, B = x_ref.shape
    x = x_ref[...].astype(jnp.float32)          # (K, B)
    scale = scale_ref[...].astype(jnp.float32)  # (K, 1)
    qmax = qmax_ref[...].astype(jnp.float32)    # (K, 1); 0 => passthrough
    w = w_ref[...].astype(jnp.float32)          # (K, 1)

    rows = jax.lax.broadcasted_iota(jnp.uint32, (K, B), 0)
    pos = (
        jax.lax.broadcasted_iota(jnp.uint32, (K, B), 1)
        + i.astype(jnp.uint32) * jnp.uint32(B)
    )
    u = sr_dither(seed_ref[0, 0], rows, pos)

    scaled = x / scale
    floor = jnp.floor(scaled)
    q = floor + (u < (scaled - floor)).astype(jnp.float32)
    q = jnp.clip(q, -qmax, qmax)
    dq = jnp.where(qmax > 0, q * scale, x)
    acc = jnp.sum(dq * w, axis=0)               # (B,)
    o_ref[...] = acc.reshape(o_ref.shape)

    @pl.when(i == 0)
    def _init():
        ss_ref[0, 0] = 0.0

    ss_ref[0, 0] += jnp.sum(acc * acc)


def _unpack_nibbles(p: jnp.ndarray) -> jnp.ndarray:
    """(..., N) uint8 -> (..., 2N) int8: low nibble first, sign-extended.

    The in-kernel half of the row-major int4 wire format
    (``kernels.ops.pack_int4_rows``); kept here so the Pallas kernel body
    and the jnp oracle run the exact same ops (bit-equality contract).
    """
    lo = (p & jnp.uint8(0x0F)).astype(jnp.int8)
    hi = ((p >> jnp.uint8(4)) & jnp.uint8(0x0F)).astype(jnp.int8)
    lo = jnp.where(lo >= 8, lo - 16, lo)
    hi = jnp.where(hi >= 8, hi - 16, hi)
    return jnp.stack([lo, hi], axis=-1).reshape(*p.shape[:-1], 2 * p.shape[-1])


def _tile_scale_cols(scale_ref, i, K, B, qblock, aligned):
    """Per-column dequant scales for grid step ``i``'s (K, B) symbol tile.

    ``aligned`` (qblock divides the logical tile width — true for every
    power-of-two block <= BLOCK_COLS, incl. the 256 default): scale_ref
    is the (K, B // qblock) slice of the scale matrix this tile owns,
    streamed per grid step by its BlockSpec exactly like the symbol
    tile, and expanded by a static repeat — VMEM stays O(K * B/qblock)
    no matter how large M grows (at M = 16M the full matrix would be
    K * 256 KB, which does NOT fit VMEM resident). Unaligned block
    sizes fall back to the whole (K, n_blocks) matrix resident + a
    positional gather (fine for the small/ragged cases that produce
    them). ``qblock`` = 0 means one per-update scale (n_blocks = 1):
    the (K, 1) column broadcasts with no gather — the PR-2 path,
    bit-exact. Positions past the last block (lane padding) clip to it
    in the gather and read padded 1.0 scales in the aligned path;
    padding symbols are exact zeros so the value there is irrelevant.
    """
    scales = scale_ref[...].astype(jnp.float32)
    if qblock <= 0 or (not aligned and scales.shape[1] == 1):
        return scales  # (K, 1) broadcast — per-row degenerate case
    if aligned:
        return jnp.repeat(scales, qblock, axis=1)  # (K, B), static
    # 2D iota (TPU requires >= 2D), flattened for the axis-1 gather
    pos = jax.lax.broadcasted_iota(jnp.int32, (1, B), 1).reshape(B) + i * B
    return jnp.take(scales, pos // qblock, axis=1, mode="clip")


def _row_coeff(w_ref, g_ref):
    """Per-row combining coefficient: w_k, or w_k * g_k under fading.

    ``g_ref`` is the (K, 1) effective channel gain column (DESIGN.md
    §12: truncated-inversion receive gain in [0, 1]; 0 = truncated
    client) — present only in the gain-aware call variants. The gain
    multiplies the weight BEFORE the symbol math, so the gained kernel
    runs exactly the ungained ops on a pre-scaled weight column: with
    ``g_ref`` absent the coefficient is untouched (bitwise the legacy
    path), and a unit gain multiplies by 1.0, which is exact in f32.
    """
    w = w_ref[...].astype(jnp.float32)
    if g_ref is not None:
        w = w * g_ref[...].astype(jnp.float32)
    return w


def _dq_superpose_kernel(
    scale_ref, w_ref, *refs, qblock=0, aligned=False, gained=False
):
    """Dequantize pre-quantized rows and superpose: acc = sum_k w_k s_k q_k
    (times the per-row channel gain g_k in the gain-aware variant).

    q_ref: (K, B) int8/int16/f32 tile — client-side quantized symbols (or
    f32 passthrough rows with scale 1). The stochastic rounding already
    happened at the client (core.quant.quantize_row_sr), so unlike
    ``_fused_kernel`` there is no dither here — just the receiver-side
    dequant+reduction over the packed wire format. scale_ref: this
    tile's slice of the blockwise scale matrix (``_tile_scale_cols``;
    n_blocks = 1: per-update). With ``gained`` an extra (K, 1) gain
    column rides between w_ref and the symbol tile — the same
    shape trick as the blockwise scale matrix, resident every grid step.
    """
    g_ref, (q_ref, o_ref) = (refs[0], refs[1:]) if gained else (None, refs)
    i = pl.program_id(0)
    K, B = q_ref.shape
    scale = _tile_scale_cols(scale_ref, i, K, B, qblock, aligned)
    dq = q_ref[...].astype(jnp.float32) * scale
    o_ref[...] = jnp.sum(dq * _row_coeff(w_ref, g_ref), axis=0).reshape(o_ref.shape)


def _dq_superpose_int4_kernel(
    scale_ref, w_ref, *refs, qblock=0, aligned=False, gained=False
):
    """int4 variant: unpack two symbols per byte in-VMEM, then dequant+sum.

    p_ref: (K, B//2) uint8 tile of row-major packed nibbles; the HBM read
    for a 4-bit cohort is 1/8 of the f32 path. Block ids index *symbol*
    positions (two per packed byte), so the scale expansion happens
    after the in-VMEM unpack.
    """
    g_ref, (p_ref, o_ref) = (refs[0], refs[1:]) if gained else (None, refs)
    i = pl.program_id(0)
    q = _unpack_nibbles(p_ref[...])
    K, B = q.shape
    scale = _tile_scale_cols(scale_ref, i, K, B, qblock, aligned)
    dq = q.astype(jnp.float32) * scale
    o_ref[...] = jnp.sum(dq * _row_coeff(w_ref, g_ref), axis=0).reshape(o_ref.shape)


def _fold_superpose_kernel(
    scale_ref, w_ref, *refs, qblock=0, aligned=False, gained=False
):
    """Streaming fold: out = acc + sum_k w_k s_k q_k (DESIGN.md §11).

    The persistent-accumulator variant of ``_dq_superpose_kernel``: the
    running (M,) superposition streams through VMEM alongside the
    micro-batch's symbol tiles, and each grid step writes the folded
    tile. Per-column math is identical to the barrier kernel plus one
    elementwise add, so fold(zeros, batch) == superpose(batch) and
    fold(fold(state, b0), b1) is exactly the left-associated group sum
    the synchronous path computes (core/ota._fold_groups). The
    gain-aware variant folds with w_k * g_k row coefficients
    (``_row_coeff``) — a wave of all-truncated rows (every g_k = 0)
    adds exact zeros and leaves the accumulator value unchanged.
    """
    g_ref, (q_ref, acc_ref, o_ref) = (refs[0], refs[1:]) if gained else (None, refs)
    i = pl.program_id(0)
    K, B = q_ref.shape
    scale = _tile_scale_cols(scale_ref, i, K, B, qblock, aligned)
    dq = q_ref[...].astype(jnp.float32) * scale
    part = jnp.sum(dq * _row_coeff(w_ref, g_ref), axis=0)
    o_ref[...] = acc_ref[...] + part.reshape(o_ref.shape)


def _fold_superpose_int4_kernel(
    scale_ref, w_ref, *refs, qblock=0, aligned=False, gained=False
):
    """int4 fold variant: in-VMEM nibble unpack, then fold into acc."""
    g_ref, (p_ref, acc_ref, o_ref) = (refs[0], refs[1:]) if gained else (None, refs)
    i = pl.program_id(0)
    q = _unpack_nibbles(p_ref[...])
    K, B = q.shape
    scale = _tile_scale_cols(scale_ref, i, K, B, qblock, aligned)
    dq = q.astype(jnp.float32) * scale
    part = jnp.sum(dq * _row_coeff(w_ref, g_ref), axis=0)
    o_ref[...] = acc_ref[...] + part.reshape(o_ref.shape)


def _packed_specs(q, scale, *, qblock, packed4):
    """Shared scaffolding of the packed superpose/fold calls.

    Returns (M, grid, in_specs, scales, w_spec_args...) — the grid, the
    normalized (and, in the aligned case, padded) scale matrix, and the
    BlockSpecs for (scale matrix, per-client column, symbol tile).

    Scale streaming: when qblock divides the logical tile width (every
    power-of-two block size <= BLOCK_COLS, incl. the 256 default), each
    grid step owns a contiguous (K, BLOCK_COLS/qblock) scale slice — a
    streamed BlockSpec, VMEM-safe at any M. The scale matrix is padded
    with 1.0 to the grid's block count (lane padding symbols are exact
    zeros, so the scale value multiplied there never shows). Unaligned
    sizes keep the whole matrix resident + in-kernel gather.
    """
    K, cols = q.shape
    bc = BLOCK_COLS // 2 if packed4 else BLOCK_COLS
    assert cols % bc == 0, (cols, bc)
    M = 2 * cols if packed4 else cols
    scales = jnp.asarray(scale, jnp.float32)
    if scales.ndim == 1:
        scales = scales.reshape(K, 1)
    n_blocks = scales.shape[1]
    grid = (cols // bc,)
    col = pl.BlockSpec((K, 1), lambda i: (0, 0))
    tile = pl.BlockSpec((K, bc), lambda i: (0, i))
    aligned = qblock > 0 and n_blocks > 1 and BLOCK_COLS % qblock == 0
    if aligned:
        bpt = BLOCK_COLS // qblock  # blocks per tile
        need = grid[0] * bpt
        if n_blocks < need:
            scales = jnp.pad(
                scales, ((0, 0), (0, need - n_blocks)), constant_values=1.0
            )
        smat = pl.BlockSpec((K, bpt), lambda i: (0, i))
    else:
        smat = pl.BlockSpec((K, n_blocks), lambda i: (0, 0))
    return M, grid, aligned, scales, smat, col, tile


def ota_packed_2d(
    q: jnp.ndarray,
    scale: jnp.ndarray,
    w: jnp.ndarray,
    *,
    gains=None,
    qblock: int = 0,
    packed4: bool = False,
    interpret: bool = False,
):
    """Dequant + weighted superpose of quantized client rows.

    q: (K, M) int8/int16/f32 symbols, or (K, M//2) uint8 when ``packed4``
    (row-major int4 nibbles; logical M = 2 * q.shape[1]). scale: (K,) or
    (K, 1) per-update scales, or the (K, n_blocks) blockwise scale
    matrix with ``qblock`` symbols per block (``core/quant.
    quantize_row_sr`` with block = qblock; last block ragged). w: (K,).
    ``gains``: optional (K,) per-row effective channel gain (DESIGN.md
    §12) — the fading/power-control receive gain multiplying each row's
    combining weight in-pass; None (the default) is the unit channel
    and runs the exact legacy program (no extra kernel input). Returns
    the (M,) f32 partial aggregate for this storage group; the caller
    combines groups and computes the AWGN power on the total (see
    core/ota.py).
    """
    K = q.shape[0]
    M, grid, aligned, scales, smat, col, tile = _packed_specs(
        q, scale, qblock=qblock, packed4=packed4
    )
    body = _dq_superpose_int4_kernel if packed4 else _dq_superpose_kernel
    gained = gains is not None
    in_specs = [smat, col] + ([col] if gained else []) + [tile]
    operands = [scales, w.reshape(K, 1).astype(jnp.float32)]
    if gained:
        operands.append(jnp.asarray(gains).reshape(K, 1).astype(jnp.float32))
    operands.append(q)
    return pl.pallas_call(
        functools.partial(body, qblock=qblock, aligned=aligned, gained=gained),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((BLOCK_COLS,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((M,), jnp.float32),
        interpret=interpret,
    )(*operands)


def ota_fold_2d(
    acc: jnp.ndarray,
    q: jnp.ndarray,
    scale: jnp.ndarray,
    w: jnp.ndarray,
    *,
    gains=None,
    qblock: int = 0,
    packed4: bool = False,
    interpret: bool = False,
):
    """Fold one packed micro-batch into a persistent (M,) accumulator.

    Same contract as ``ota_packed_2d`` plus ``acc``: the running
    superposition state ((M,) f32, M the logical symbol count). Returns
    acc + the micro-batch's partial aggregate — the streaming-round
    primitive (DESIGN.md §11): arrivals fold in batch by batch instead
    of one (K, M) barrier, and HBM traffic per fold is one read of the
    batch symbols + one read/write of the accumulator. ``gains``: the
    optional per-row channel gain column as in ``ota_packed_2d``.
    Oracle: ``ref.ota_fold_ref`` (bit-equal).
    """
    K = q.shape[0]
    M, grid, aligned, scales, smat, col, tile = _packed_specs(
        q, scale, qblock=qblock, packed4=packed4
    )
    assert acc.shape == (M,), (acc.shape, M)
    body = _fold_superpose_int4_kernel if packed4 else _fold_superpose_kernel
    gained = gains is not None
    acc_spec = pl.BlockSpec((BLOCK_COLS,), lambda i: (i,))
    in_specs = [smat, col] + ([col] if gained else []) + [tile, acc_spec]
    operands = [scales, w.reshape(K, 1).astype(jnp.float32)]
    if gained:
        operands.append(jnp.asarray(gains).reshape(K, 1).astype(jnp.float32))
    operands.extend([q, acc.astype(jnp.float32)])
    return pl.pallas_call(
        functools.partial(body, qblock=qblock, aligned=aligned, gained=gained),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((BLOCK_COLS,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((M,), jnp.float32),
        interpret=interpret,
    )(*operands)


def ota_fused_2d(
    x: jnp.ndarray,
    scale: jnp.ndarray,
    qmax: jnp.ndarray,
    w: jnp.ndarray,
    seed: jnp.ndarray,
    *,
    interpret: bool = False,
):
    """x: (K, M) with M % BLOCK_COLS == 0; scale/qmax/w: (K,); seed: ().

    Returns (acc (M,) f32, sumsq (1, 1) f32) — the pre-noise aggregate and
    its squared norm.
    """
    K, M = x.shape
    assert M % BLOCK_COLS == 0, M
    grid = (M // BLOCK_COLS,)
    scalar = pl.BlockSpec((1, 1), lambda i: (0, 0))
    col = pl.BlockSpec((K, 1), lambda i: (0, 0))
    tile = pl.BlockSpec((K, BLOCK_COLS), lambda i: (0, i))
    return pl.pallas_call(
        _fused_kernel,
        grid=grid,
        in_specs=[scalar, col, col, col, tile],
        out_specs=[
            pl.BlockSpec((BLOCK_COLS,), lambda i: (i,)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M,), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(
        seed.reshape(1, 1).astype(jnp.uint32),
        scale.reshape(K, 1).astype(jnp.float32),
        qmax.reshape(K, 1).astype(jnp.float32),
        w.reshape(K, 1).astype(jnp.float32),
        x,
    )
