"""Pallas TPU kernel: int8-weight matmul with fused per-channel dequant.

Low-precision clients hold int8/int4 weights; their forward pass is
``x @ (w_q * scale)``. Materialising the dequantised weights costs a full
f32 copy of the weight matrix in HBM — this kernel dequantises *inside*
the MXU pipeline: (bm, bk) x (bk, bn) tiles stream through VMEM, weights
are upcast per-tile, and the product accumulates in an f32 VMEM
accumulator across the k grid dimension.

int4 runs through the same kernel: pack int4 pairs into int8 offline and
dequantise with a doubled scale table (ops.py handles the packing).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BM, BK, BN = 128, 128, 128


def _qmm_kernel(x_ref, w_ref, scale_ref, o_ref, acc_ref, *, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)  # (BM, BK)
    w = w_ref[...].astype(jnp.float32)  # (BK, BN) int8 -> f32
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == n_k - 1)
    def _done():
        scale = scale_ref[...].astype(jnp.float32)  # (1, BN)
        o_ref[...] = (acc_ref[...] * scale).astype(o_ref.dtype)


def qmatmul(
    x: jnp.ndarray, w_q: jnp.ndarray, scale: jnp.ndarray, *, interpret: bool = False
) -> jnp.ndarray:
    """x: (M, K); w_q: (K, N) int8; scale: (N,) f32. M,K,N % 128 == 0."""
    M, K = x.shape
    K2, N = w_q.shape
    assert K == K2 and M % BM == 0 and K % BK == 0 and N % BN == 0
    n_k = K // BK
    grid = (M // BM, N // BN, n_k)
    return pl.pallas_call(
        functools.partial(_qmm_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BM, BK), lambda i, j, k: (i, k)),
            pl.BlockSpec((BK, BN), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, BN), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((BM, BN), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((BM, BN), jnp.float32)],
        interpret=interpret,
    )(x, w_q, scale.reshape(1, N))
