"""Pallas TPU kernel: fused fake-quantization (scale → round → clip →
dequant), optionally with stochastic rounding.

This is the per-round hot loop of MP-OTA-FL: every client quantizes its
full update tensor every round. The kernel streams the tensor through
VMEM in (8·k, 128) tiles (VPU lanes), keeping the scalar scale in SMEM —
one HBM read + one write per element, no intermediate materialisation
(the jnp reference materialises scaled / rounded / clipped copies).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.quant import qrange

BLOCK_ROWS = 256
LANES = 128


def _fq_kernel(scale_ref, x_ref, o_ref, *, qmax: float):
    scale = scale_ref[0, 0]
    scaled = x_ref[...].astype(jnp.float32) / scale
    q = jnp.round(scaled)
    q = jnp.clip(q, -qmax, qmax)
    o_ref[...] = (q * scale).astype(o_ref.dtype)


def _fq_stoch_kernel(scale_ref, x_ref, noise_ref, o_ref, *, qmax: float):
    scale = scale_ref[0, 0]
    scaled = x_ref[...].astype(jnp.float32) / scale
    floor = jnp.floor(scaled)
    q = floor + (noise_ref[...] < (scaled - floor)).astype(jnp.float32)
    q = jnp.clip(q, -qmax, qmax)
    o_ref[...] = (q * scale).astype(o_ref.dtype)


def fake_quant_2d(
    x: jnp.ndarray,
    scale: jnp.ndarray,
    bits: int,
    noise: Optional[jnp.ndarray] = None,
    *,
    interpret: bool = False,
) -> jnp.ndarray:
    """x: (M, 128k) 2-D, M % BLOCK_ROWS == 0. scale: () f32."""
    M, N = x.shape
    assert M % BLOCK_ROWS == 0 and N % LANES == 0, (M, N)
    qmax = float(qrange(bits))
    grid = (M // BLOCK_ROWS,)
    scale2d = scale.reshape(1, 1).astype(jnp.float32)

    block = pl.BlockSpec((BLOCK_ROWS, N), lambda i: (i, 0))
    if noise is None:
        return pl.pallas_call(
            functools.partial(_fq_kernel, qmax=qmax),
            grid=grid,
            in_specs=[pl.BlockSpec((1, 1), lambda i: (0, 0)), block],
            out_specs=block,
            out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            interpret=interpret,
        )(scale2d, x)
    return pl.pallas_call(
        functools.partial(_fq_stoch_kernel, qmax=qmax),
        grid=grid,
        in_specs=[pl.BlockSpec((1, 1), lambda i: (0, 0)), block, block],
        out_specs=block,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(scale2d, x, noise.astype(jnp.float32))
