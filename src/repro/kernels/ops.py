"""jit'd public wrappers around the Pallas kernels.

Handles shape normalisation (flatten/pad to tile multiples), scale
computation, and backend selection: on CPU (this container) the kernels
execute in ``interpret=True`` mode — the kernel *body* runs exactly as it
would on TPU, which is what the allclose tests validate.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.quant import qrange
from repro.kernels import ota_aggregate as _ota
from repro.kernels import ota_fused as _otaf
from repro.kernels import qmatmul as _qmm
from repro.kernels import quantize as _q


def _on_cpu() -> bool:
    return jax.devices()[0].platform == "cpu"


def _pad_to(x: jnp.ndarray, m: int, axis: int = 0) -> Tuple[jnp.ndarray, int]:
    n = x.shape[axis]
    pad = (-n) % m
    if pad:
        widths = [(0, 0)] * x.ndim
        widths[axis] = (0, pad)
        x = jnp.pad(x, widths)
    return x, pad


@functools.partial(jax.jit, static_argnames=("bits", "stochastic"))
def fake_quant(
    x: jnp.ndarray,
    bits: int,
    *,
    stochastic: bool = False,
    key: Optional[jax.Array] = None,
) -> jnp.ndarray:
    """Per-tensor fake-quant of an arbitrary-shape tensor via the kernel."""
    interpret = _on_cpu()
    qmax = float(qrange(bits))
    amax = jnp.max(jnp.abs(x)).astype(jnp.float32)
    scale = jnp.maximum(amax, 1e-12) / qmax
    flat = x.reshape(-1)
    cols = _q.LANES
    rows_block = _q.BLOCK_ROWS
    flat, pad = _pad_to(flat, cols * rows_block)
    x2 = flat.reshape(-1, cols)
    noise = None
    if stochastic:
        noise = jax.random.uniform(key, x2.shape, jnp.float32)
    out = _q.fake_quant_2d(x2, scale, bits, noise, interpret=interpret)
    out = out.reshape(-1)
    if pad:
        out = out[:-pad]
    return out.reshape(x.shape).astype(x.dtype)


@jax.jit
def ota_aggregate(
    x: jnp.ndarray, w: jnp.ndarray, noise: jnp.ndarray, noise_std: jnp.ndarray
) -> jnp.ndarray:
    """Superpose K flat client streams. x: (K, M); w: (K,); noise: (M,)."""
    interpret = _on_cpu()
    M = x.shape[1]
    xp, pad = _pad_to(x, _ota.BLOCK_COLS, axis=1)
    np_, _ = _pad_to(noise, _ota.BLOCK_COLS)
    out = _ota.ota_aggregate_2d(xp, w, np_, jnp.asarray(noise_std), interpret=interpret)
    return out[:M]


@jax.jit
def ota_quantize_superpose(
    x: jnp.ndarray,
    scale: jnp.ndarray,
    qmax: jnp.ndarray,
    w: jnp.ndarray,
    seed: jnp.ndarray,
):
    """Fused per-client stochastic quantize -> dequant -> weighted superpose.

    x: (K, M); scale/qmax/w: (K,) (qmax == 0 => fp32 passthrough row);
    seed: () uint32 driving the in-kernel positional rounding dither.
    Returns (acc (M,) f32, sumsq () f32). One streaming pass on TPU; the
    jnp oracle with identical semantics is ``ref.ota_fused_ref``.

    Interpret mode everywhere but TPU: the kernel's cross-grid-step
    sumsq accumulation relies on TPU sequential-grid semantics and would
    race under a parallel (GPU) grid.
    """
    interpret = jax.devices()[0].platform != "tpu"
    M = x.shape[1]
    xp, _ = _pad_to(x, _otaf.BLOCK_COLS, axis=1)
    acc, ss = _otaf.ota_fused_2d(
        xp, scale, qmax, w, jnp.asarray(seed), interpret=interpret
    )
    return acc[:M], ss.reshape(())


@functools.partial(jax.jit, static_argnames=("qblock", "packed4"))
def ota_dequant_superpose(
    q: jnp.ndarray,
    scale: jnp.ndarray,
    w: jnp.ndarray,
    *,
    gains=None,
    qblock: int = 0,
    packed4: bool = False,
):
    """Receiver half of the packed uplink: dequant + weighted superpose.

    q: (K, M) int8/int16/f32 pre-quantized client symbols, or (K, M//2)
    uint8 row-major int4 nibbles when ``packed4`` (``pack_int4_rows``).
    scale: (K,) per-update scales or the (K, n_blocks) blockwise scale
    matrix (``qblock`` symbols per scale; 0 = per-update). w: (K,).
    ``gains``: optional (K,) per-row effective channel gain (fading +
    truncated channel inversion, ``core/channel.py``, DESIGN.md §12) —
    each row's combining coefficient becomes w_k * g_k inside the pass;
    None is the unit channel and runs the exact legacy program.
    Returns the (M,) f32 partial aggregate for this storage group. The
    stochastic quantization happened client-side
    (``core.quant.quantize_row_sr``); this pass never materialises the
    f32 (K, M) matrix — the unpack runs inside the kernel tile. Oracle:
    ``ref.ota_packed_ref``. Interpret mode off-TPU (CPU correctness tool;
    the jnp oracle is the CPU perf path, as with ota_quantize_superpose).
    """
    interpret = jax.devices()[0].platform != "tpu"
    bc = _otaf.BLOCK_COLS // 2 if packed4 else _otaf.BLOCK_COLS
    M = 2 * q.shape[1] if packed4 else q.shape[1]
    qp, _ = _pad_to(q, bc, axis=1)
    out = _otaf.ota_packed_2d(
        qp, scale, w, gains=gains, qblock=qblock, packed4=packed4, interpret=interpret
    )
    return out[:M]


@functools.partial(jax.jit, static_argnames=("k", "use_kernel"))
def topk_cosine(
    qm: jnp.ndarray,
    recs: jnp.ndarray,
    scales: Optional[jnp.ndarray],
    n: jnp.ndarray,
    *,
    k: int,
    use_kernel: bool = True,
):
    """Batched cosine top-k over an arena record slab.

    qm: (Q, D) f32 unit-norm query batch; recs: (Np, D) f32 or int8
    capacity slab with Np % topk_similarity.TILE_N == 0; scales:
    (Np, D // qblock) f32 scale grid (int8 recs) or None; n: () traced
    live record count — the jit cache keys on (Q-pad, Np, D, k, storage
    class), never on n, so arena appends don't recompile. k is static,
    <= topk_similarity.TOPK_LANES.

    Returns (scores (Q, k) f32, idx (Q, k) int32) under the engine's tie
    contract (descending score, ties by ascending index). With
    ``use_kernel`` the Pallas kernel runs (interpret mode off-TPU);
    otherwise the bit-equal jnp oracle ``ref.topk_similarity_ref`` — the
    CPU perf path, as with the OTA kernels.
    """
    from repro.kernels import ref as _ref
    from repro.kernels import topk_similarity as _tk

    Q, D = qm.shape
    assert 0 < k <= _tk.TOPK_LANES, k
    Qp = -(-Q // 8) * 8  # f32 sublane multiple
    qp = jnp.pad(qm, ((0, Qp - Q), (0, 0))) if Qp != Q else qm
    if use_kernel:
        interpret = jax.devices()[0].platform != "tpu"
        s, i = _tk.topk_similarity_2d(qp, recs, scales, n, interpret=interpret)
    else:
        s, i = _ref.topk_similarity_ref(qp, recs, scales, n)
    return s[:Q, :k], i[:Q, :k]


@functools.partial(jax.jit, static_argnames=("k", "use_kernel", "mesh"))
def topk_cosine_sharded(
    qm: jnp.ndarray,
    recs: jnp.ndarray,
    scales: Optional[jnp.ndarray],
    n: jnp.ndarray,
    *,
    k: int,
    mesh,
    use_kernel: bool = False,
):
    """Mesh-sharded ``topk_cosine``: the record slab rows place across
    the ``data`` axis of ``mesh`` (DESIGN.md §15).

    recs: (Np, D) capacity slab with Np divisible by
    shards * topk_similarity.TILE_N (the engine pads with the arena's
    own zero-row/unit-scale convention); scales row-shard alongside.
    Every shard runs the identical tile loop on its row block with its
    local live count — shard boundaries are TILE_N-aligned, so each
    per-tile dot is literally one of the unsharded path's dots and the
    per-record scores are bit-equal. The merge then re-sorts the
    per-shard candidate lanes with ``lax.top_k``: within a shard the
    lanes are already (desc score, asc index)-ordered and shards
    concatenate in ascending index-range order, so positional ties
    resolve exactly per the engine tie contract (descending score,
    ties by ascending global index) and the result is bit-identical to
    ``topk_cosine`` — scores and indices. k <= TOPK_LANES guarantees
    any global top-k member survives its shard's lane budget.
    """
    from jax.experimental.shard_map import shard_map
    from repro.kernels import ref as _ref
    from repro.kernels import topk_similarity as _tk

    P = jax.sharding.PartitionSpec
    n_shards = mesh.shape["data"]
    Np = recs.shape[0]
    assert Np % (n_shards * _tk.TILE_N) == 0, (Np, n_shards)
    rows = Np // n_shards
    Q, D = qm.shape
    assert 0 < k <= _tk.TOPK_LANES, k
    Qp = -(-Q // 8) * 8  # f32 sublane multiple
    qp = jnp.pad(qm, ((0, Qp - Q), (0, 0))) if Qp != Q else qm
    interpret = jax.devices()[0].platform != "tpu"

    def _local_topk(qloc, rloc, sloc, nloc):
        lo = jax.lax.axis_index("data") * rows
        n_local = jnp.clip(nloc - lo, 0, rows)
        if use_kernel:
            s, i = _tk.topk_similarity_2d(qloc, rloc, sloc, n_local,
                                          interpret=interpret)
        else:
            s, i = _ref.topk_similarity_ref(qloc, rloc, sloc, n_local)
        return s[None], (i + lo)[None]

    if scales is None:
        body = lambda q_, r_, n_: _local_topk(q_, r_, None, n_)
        in_specs = (P(), P("data"), P())
        args = (qp, recs, n)
    else:
        body = _local_topk
        in_specs = (P(), P("data"), P("data"), P())
        args = (qp, recs, scales, n)
    s, i = shard_map(
        body, mesh=mesh, in_specs=in_specs, out_specs=(P("data"), P("data"))
    )(*args)
    # (shards, Qp, LANES) candidates -> flatten the shard axis in index
    # order: every tied set is then positionally ascending-index, and
    # lax.top_k keeps earliest positions among ties — the same merge
    # mechanism (and hence the same tie contract) as the unsharded
    # running merge.
    cand_s = jnp.swapaxes(s, 0, 1).reshape(Qp, n_shards * _tk.TOPK_LANES)
    cand_i = jnp.swapaxes(i, 0, 1).reshape(Qp, n_shards * _tk.TOPK_LANES)
    v, a = jax.lax.top_k(cand_s, k)
    return v[:Q], jnp.take_along_axis(cand_i, a, axis=1)[:Q]


@functools.partial(jax.jit, static_argnames=("qblock", "packed4"))
def ota_fold_packed(
    acc: jnp.ndarray,
    q: jnp.ndarray,
    scale: jnp.ndarray,
    w: jnp.ndarray,
    *,
    gains=None,
    qblock: int = 0,
    packed4: bool = False,
):
    """Fold one packed micro-batch into the persistent superposition state.

    The streaming-round primitive (DESIGN.md §11): acc is the running
    (M,) f32 accumulator (start from zeros or a prior
    ``ota_dequant_superpose`` partial), q/scale/w one micro-batch of
    same-storage-class client rows exactly as in
    ``ota_dequant_superpose`` — including the optional (K,) per-row
    channel ``gains`` (DESIGN.md §12; None = unit channel, the exact
    legacy program). Returns acc + the batch's weighted dequantized
    superposition, so a round becomes
    fold(fold(fold(state, batch0), batch1), ...) instead of one (K, M)
    barrier. Oracle: ``ref.ota_fold_ref`` (bit-equal; the jnp path is
    the CPU perf path, as with the other OTA kernels).
    """
    interpret = jax.devices()[0].platform != "tpu"
    bc = _otaf.BLOCK_COLS // 2 if packed4 else _otaf.BLOCK_COLS
    M = 2 * q.shape[1] if packed4 else q.shape[1]
    qp, _ = _pad_to(q, bc, axis=1)
    Mp = 2 * qp.shape[1] if packed4 else qp.shape[1]
    accp, _ = _pad_to(acc, Mp)
    out = _otaf.ota_fold_2d(
        accp,
        qp,
        scale,
        w,
        gains=gains,
        qblock=qblock,
        packed4=packed4,
        interpret=interpret,
    )
    return out[:M]


@jax.jit
def qmatmul(x: jnp.ndarray, w_q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """x (M, K) @ dequant(w_q (K, N) int8; per-channel scale (N,))."""
    interpret = _on_cpu()
    M, K = x.shape
    _, N = w_q.shape
    xp, pm = _pad_to(x, _qmm.BM, axis=0)
    xp, pk = _pad_to(xp, _qmm.BK, axis=1)
    wp, _ = _pad_to(w_q, _qmm.BK, axis=0)
    wp, pn = _pad_to(wp, _qmm.BN, axis=1)
    sp, _ = _pad_to(scale, _qmm.BN)
    out = _qmm.qmatmul(xp, wp, sp, interpret=interpret)
    return out[:M, :N]


@functools.partial(jax.jit, static_argnames=("causal",))
def flash_mha(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *, causal: bool = True
) -> jnp.ndarray:
    """Multi-head flash attention. q: (B, S, H, D); k/v: (B, S, KV, D).

    GQA handled by repeating KV heads to H (zero-copy broadcast reshape);
    sequences padded to the kernel tile size.
    """
    from repro.kernels import flash_attention as _fa

    B, Sq, H, D = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    pad_q = (-Sq) % _fa.BQ
    pad_k = (-Sk) % _fa.BK
    qf = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0))) if pad_q else q
    kf = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else k
    vf = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else v
    # Padding: padded query rows are sliced off below; padded KEY rows sit
    # at positions >= Sk, which causal masking (q_pos >= k_pos) hides from
    # every real query row — so causal=True handles padding for free.
    # (Non-causal callers must pass tile-aligned Sk.)
    qf = qf.swapaxes(1, 2).reshape(B * H, Sq + pad_q, D)
    kf = kf.swapaxes(1, 2).reshape(B * H, Sk + pad_k, D)
    vf = vf.swapaxes(1, 2).reshape(B * H, Sk + pad_k, D)
    out = _fa.flash_attention(qf, kf, vf, causal=causal, interpret=_on_cpu())
    out = out.reshape(B, H, Sq + pad_q, D).swapaxes(1, 2)
    return out[:, :Sq]


def quantize_weights(w: jnp.ndarray, bits: int = 8) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-output-channel symmetric int8 quantization for qmatmul."""
    qmax = qrange(bits)
    amax = jnp.max(jnp.abs(w), axis=0)
    scale = jnp.maximum(amax, 1e-12) / qmax
    q = jnp.clip(jnp.round(w / scale[None, :]), -qmax, qmax).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


# ---------------------------------------------------------------------------
# int4: pack two nibbles per int8 byte; the same qmatmul kernel consumes the
# unpacked representation (TPU int4 matmul via int8 lanes)
# ---------------------------------------------------------------------------


def pack_int4(q: jnp.ndarray) -> jnp.ndarray:
    """q: int8 values in [-8, 7], even first-dim -> (K//2, N) packed bytes."""
    K = q.shape[0]
    assert K % 2 == 0, "pack_int4 needs an even K dim"
    lo = (q[0::2].astype(jnp.uint8)) & 0x0F
    hi = (q[1::2].astype(jnp.uint8)) & 0x0F
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_int4(packed: jnp.ndarray) -> jnp.ndarray:
    """Inverse of pack_int4 -> int8 in [-8, 7], shape (2*Kp, N)."""
    lo = (packed & 0x0F).astype(jnp.int8)
    hi = ((packed >> 4) & 0x0F).astype(jnp.int8)
    # sign-extend the 4-bit two's complement values
    lo = jnp.where(lo >= 8, lo - 16, lo)
    hi = jnp.where(hi >= 8, hi - 16, hi)
    Kp, N = packed.shape
    out = jnp.zeros((2 * Kp, N), jnp.int8)
    out = out.at[0::2].set(lo)
    out = out.at[1::2].set(hi)
    return out


def pack_int4_rows(q: jnp.ndarray) -> jnp.ndarray:
    """Row-major int4 pack: (..., M) int values in [-8, 7] -> (..., ceil(M/2))
    uint8, adjacent *elements* sharing a byte (low nibble = even index).

    The uplink wire variant of ``pack_int4`` (which pairs adjacent *rows*
    for the weight layout): a client's flat update row stays a row, at
    half the bytes. Odd M is zero-padded by one symbol; ``unpack_int4_rows``
    takes the logical length to trim it back.
    """
    M = q.shape[-1]
    if M % 2:
        pad = [(0, 0)] * (q.ndim - 1) + [(0, 1)]
        q = jnp.pad(q, pad)
    lo = q[..., 0::2].astype(jnp.uint8) & 0x0F
    hi = q[..., 1::2].astype(jnp.uint8) & 0x0F
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_int4_rows(packed: jnp.ndarray, n: Optional[int] = None) -> jnp.ndarray:
    """Inverse of ``pack_int4_rows``: (..., P) uint8 -> (..., n) int8.

    ``n`` trims the trailing pad symbol of an odd-length row (defaults to
    2P). Same nibble math as the in-kernel unpack
    (``ota_fused._unpack_nibbles``) — the bit-equality contract between
    the packed aggregation kernel and its jnp oracle rides on that.
    """
    from repro.kernels.ota_fused import _unpack_nibbles

    out = _unpack_nibbles(packed)
    return out if n is None else out[..., :n]


def quantize_weights_int4(w: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-channel symmetric int4: returns (packed (K//2, N) uint8, scale)."""
    q, scale = quantize_weights(w, bits=4)
    return pack_int4(q), scale


@jax.jit
def qmatmul_int4(
    x: jnp.ndarray, w_packed: jnp.ndarray, scale: jnp.ndarray
) -> jnp.ndarray:
    """x (M, K) @ dequant(int4-packed weights (K//2, N))."""
    return qmatmul(x, unpack_int4(w_packed), scale)
