"""Pallas TPU kernel: causal flash attention (online softmax).

Motivated directly by the §Roofline result that dense training/prefill is
memory-bound with the score pipeline (QK^T → mask → softmax → PV) as a
large HBM consumer in the jnp formulation: this kernel keeps the running
(m, l, acc) statistics in VMEM scratch across the KV grid dimension, so
scores never touch HBM.

Grid: (batch·kv_heads·q_groups, n_q_blocks, n_k_blocks) with the KV axis
innermost; BlockSpecs stream (Bq, D) query and (Bk, D) key/value tiles
through VMEM. Causal masking is positional within the tile; fully-masked
tiles still execute (the grid is rectangular) — the structural skip lives
at the jnp layer (layers.chunked_attention), this kernel is the per-tile
engine.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BQ = 128
BK = 128
NEG_INF = -1e30


def _flash_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    m_ref,
    l_ref,
    acc_ref,
    *,
    n_k: int,
    causal: bool,
    scale: float,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]  # (BQ, D)
    k = k_ref[0]  # (BK, D)
    v = v_ref[0]
    s = (
        jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        * scale
    )
    if causal:
        q_pos = qi * BQ + jax.lax.broadcasted_iota(jnp.int32, (BQ, BK), 0)
        k_pos = ki * BK + jax.lax.broadcasted_iota(jnp.int32, (BQ, BK), 1)
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
    pv = jax.lax.dot_general(
        p.astype(v.dtype),
        v,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    acc_ref[...] = acc_ref[...] * corr + pv
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == n_k - 1)
    def _done():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    interpret: bool = False,
) -> jnp.ndarray:
    """q: (BH, Sq, D); k/v: (BH, Sk, D). Sq % BQ == Sk % BK == 0.

    BH is the flattened batch·heads axis (GQA grouping is done by the
    caller — see ops.flash_mha).
    """
    BH, Sq, D = q.shape
    _, Sk, _ = k.shape
    assert Sq % BQ == 0 and Sk % BK == 0, (Sq, Sk)
    n_q = Sq // BQ
    n_k = Sk // BK
    grid = (BH, n_q, n_k)
    return pl.pallas_call(
        functools.partial(_flash_kernel, n_k=n_k, causal=causal, scale=D**-0.5),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, BQ, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, BK, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, BK, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, BQ, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((BQ, 1), jnp.float32),  # running max
            pltpu.VMEM((BQ, 1), jnp.float32),  # running denom
            pltpu.VMEM((BQ, D), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
