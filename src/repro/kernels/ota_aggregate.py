"""Pallas TPU kernel: mixed-precision OTA superposition.

Server-side hot loop: superpose K dequantised client streams with their
FedAvg/channel weights and inject the receiver noise —
``y[m] = sum_k w[k] * x[k, m] + noise_std * n[m]`` — in one pass.

Tiling: the client axis K stays resident (it is small, <= a round's
cohort), the parameter axis streams through VMEM in (K, bm·128) tiles.
The weighted reduction maps onto the VPU as a K-step fused
multiply-accumulate; fusing the noise injection saves a full extra
HBM round-trip over the two-op jnp formulation.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_COLS = 2048
LANES = 128


def _ota_kernel(w_ref, std_ref, x_ref, noise_ref, o_ref):
    # x_ref: (K, BLOCK_COLS); w_ref: (K, 1) SMEM-resident
    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)  # (K, 1)
    acc = jnp.sum(x * w, axis=0)  # (BLOCK_COLS,)
    o_ref[...] = (acc + std_ref[0, 0] * noise_ref[...]).reshape(o_ref.shape)


def ota_aggregate_2d(
    x: jnp.ndarray,
    w: jnp.ndarray,
    noise: jnp.ndarray,
    noise_std: jnp.ndarray,
    *,
    interpret: bool = False,
) -> jnp.ndarray:
    """x: (K, M) with M % BLOCK_COLS == 0; w: (K,); noise: (M,)."""
    K, M = x.shape
    assert M % BLOCK_COLS == 0, M
    grid = (M // BLOCK_COLS,)
    w2d = w.reshape(K, 1).astype(jnp.float32)
    std2d = noise_std.reshape(1, 1).astype(jnp.float32)
    return pl.pallas_call(
        _ota_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((K, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((K, BLOCK_COLS), lambda i: (0, i)),
            pl.BlockSpec((BLOCK_COLS,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_COLS,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((M,), jnp.float32),
        interpret=interpret,
    )(w2d, std2d, x, noise.astype(jnp.float32))
