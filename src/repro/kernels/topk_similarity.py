"""Pallas TPU kernel: fused batched similarity + running top-k retrieval.

The RAG control plane's hot query (DESIGN.md §10): score a (Q, D) batch
of unit-norm query embeddings against the arena's (N, D) record slab and
return each query's k best records — without ever materialising the
(Q, N) score matrix. One sequential pass over (TILE_N, D) record tiles:

    for each record tile i:
        s      = q @ tile.T                  (MXU; cosine via unit norms)
        s     |= -inf past the live count    (arena capacity padding)
        topk   = top_k([topk_scores | s])    (running (Q, KP) merge)

The running top-k (scores + record indices) lives in the two output refs,
revisited every grid step — the same sequential-grid accumulation pattern
as ``ota_fused``'s sum-of-squares. int8 arena tiles (the blockwise
storage class of ``retrieval/arena.py``) are dequantized in-pass from
their (TILE_N, D/qblock) scale-grid slice, so the HBM read of an int8
store is ~1/3.8 of the f32 slab.

Tie contract (the bit-equality anchor): descending score, equal scores by
ascending record index. ``jax.lax.top_k`` keeps the lower candidate
position on ties, and every merge concatenates the running list (all
indices from earlier tiles, already tie-ordered) before the current tile
(ascending positions), so the invariant holds inductively and the result
is exactly the top-k a stable brute-force scan produces. The jnp oracle
(``ref.topk_similarity_ref``) replays the identical tile loop, so kernel
and oracle are bit-equal in interpret mode.

The live record count ``n`` is a *traced* scalar: the arena hands the
kernel its zero-padded capacity slab, so the jit cache keys on
(Q-pad, capacity, D, k, storage class) and appends never recompile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_N = 256  # records per grid step; arena capacity is a multiple
TOPK_LANES = 128  # running top-k width (one lane tile); k <= TOPK_LANES


def _merge_topk(score_ref, idx_ref, s, pos, i):
    """Fold one tile's (Q, T) scores into the running (Q, KP) top-k."""
    @pl.when(i == 0)
    def _init():
        score_ref[...] = jnp.full(score_ref.shape, -jnp.inf, jnp.float32)
        idx_ref[...] = jnp.zeros(idx_ref.shape, jnp.int32)

    cand_s = jnp.concatenate([score_ref[...], s], axis=1)
    cand_i = jnp.concatenate([idx_ref[...], pos], axis=1)
    v, a = jax.lax.top_k(cand_s, score_ref.shape[1])
    score_ref[...] = v
    idx_ref[...] = jnp.take_along_axis(cand_i, a, axis=1)


def _tile_scores(q, rec, i, n):
    s = jnp.dot(q, rec.T, preferred_element_type=jnp.float32)
    Qp, T = s.shape
    pos = jax.lax.broadcasted_iota(jnp.int32, (Qp, T), 1) + i * T
    return jnp.where(pos < n, s, -jnp.inf), pos


def _topk_f32_kernel(n_ref, q_ref, r_ref, score_ref, idx_ref):
    i = pl.program_id(0)
    s, pos = _tile_scores(q_ref[...], r_ref[...], i, n_ref[0, 0])
    _merge_topk(score_ref, idx_ref, s, pos, i)


def _topk_int8_kernel(n_ref, q_ref, r_ref, s_ref, score_ref, idx_ref, *, qblock):
    """int8 variant: dequantize the record tile in-VMEM from its blockwise
    scale slice (``qblock`` dims per scale, the arena storage class)."""
    i = pl.program_id(0)
    rec = r_ref[...].astype(jnp.float32) * jnp.repeat(
        s_ref[...].astype(jnp.float32), qblock, axis=1
    )
    s, pos = _tile_scores(q_ref[...], rec, i, n_ref[0, 0])
    _merge_topk(score_ref, idx_ref, s, pos, i)


def topk_similarity_2d(qm, recs, scales, n, *, interpret: bool = False):
    """qm: (Qp, D) f32 queries; recs: (Np, D) f32 or int8 record slab with
    Np % TILE_N == 0 (the arena capacity buffer, zero-padded); scales:
    (Np, D // qblock) f32 scale grid for int8 recs, None for f32; n: ()
    live record count (positions >= n score -inf).

    Returns (scores (Qp, TOPK_LANES) f32, idx (Qp, TOPK_LANES) int32),
    each row sorted by the tie contract; entries past min(n, TOPK_LANES)
    are -inf. ``jax.lax.top_k`` inside the body is exercised in interpret
    mode (the CPU contract of this repo); on real TPU it requires a
    Mosaic lowering — fall back to the jnp oracle if unsupported.
    """
    Qp, D = qm.shape
    Np = recs.shape[0]
    assert Np % TILE_N == 0, (Np, TILE_N)
    grid = (Np // TILE_N,)
    scalar = pl.BlockSpec((1, 1), lambda i: (0, 0))
    qspec = pl.BlockSpec((Qp, D), lambda i: (0, 0))
    rspec = pl.BlockSpec((TILE_N, D), lambda i: (i, 0))
    out_specs = [
        pl.BlockSpec((Qp, TOPK_LANES), lambda i: (0, 0)),
        pl.BlockSpec((Qp, TOPK_LANES), lambda i: (0, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((Qp, TOPK_LANES), jnp.float32),
        jax.ShapeDtypeStruct((Qp, TOPK_LANES), jnp.int32),
    ]
    n2d = jnp.asarray(n, jnp.int32).reshape(1, 1)
    if recs.dtype == jnp.int8:
        nb = scales.shape[1]
        assert D % nb == 0, (D, nb)
        sspec = pl.BlockSpec((TILE_N, nb), lambda i: (i, 0))
        return pl.pallas_call(
            functools.partial(_topk_int8_kernel, qblock=D // nb),
            grid=grid,
            in_specs=[scalar, qspec, rspec, sspec],
            out_specs=out_specs,
            out_shape=out_shape,
            interpret=interpret,
        )(n2d, qm, recs, scales)
    return pl.pallas_call(
        _topk_f32_kernel,
        grid=grid,
        in_specs=[scalar, qspec, rspec],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(n2d, qm, recs)
