"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

The quantization semantics are shared with ``repro.core.quant`` — these
re-exports *are* the reference the kernels are tested against.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quant import qrange


def fake_quant_ref(
    x: jnp.ndarray, scale: jnp.ndarray, bits: int, noise: Optional[jnp.ndarray] = None
) -> jnp.ndarray:
    """Fake-quantize with a precomputed per-tensor scale.

    noise: optional uniform [0,1) array (stochastic rounding); None = RTN.
    """
    qmax = float(qrange(bits))
    scaled = x.astype(jnp.float32) / scale
    if noise is None:
        q = jnp.round(scaled)
    else:
        floor = jnp.floor(scaled)
        q = floor + (noise < (scaled - floor)).astype(jnp.float32)
    q = jnp.clip(q, -qmax, qmax)
    return q * scale


def ota_fused_ref(
    x: jnp.ndarray,
    scale: jnp.ndarray,
    qmax: jnp.ndarray,
    w: jnp.ndarray,
    seed: jnp.ndarray,
):
    """Oracle for the fused OTA data-plane kernel (see ota_fused.py).

    x: (K, M); scale/qmax/w: (K,); seed: () uint32 for the positional
    stochastic-rounding dither. qmax == 0 marks an unquantized (fp32,
    bits >= 32) client. Returns (acc (M,), sumsq () f32): the
    stochastic-quantize -> dequantize -> weighted superposition of the K
    client streams, plus the aggregate's squared norm (the AWGN power
    calibration input).
    """
    from repro.kernels.ota_fused import sr_dither

    K, M = x.shape
    x = x.astype(jnp.float32)
    scale = scale.reshape(-1, 1).astype(jnp.float32)
    qmax = qmax.reshape(-1, 1).astype(jnp.float32)
    w = w.reshape(-1, 1).astype(jnp.float32)
    u = sr_dither(
        jnp.asarray(seed),
        jax.lax.broadcasted_iota(jnp.uint32, (K, M), 0),
        jax.lax.broadcasted_iota(jnp.uint32, (K, M), 1),
    )
    scaled = x / scale
    floor = jnp.floor(scaled)
    q = floor + (u < (scaled - floor)).astype(jnp.float32)
    q = jnp.clip(q, -qmax, qmax)
    dq = jnp.where(qmax > 0, q * scale, x)
    acc = jnp.sum(dq * w, axis=0)
    return acc, jnp.sum(acc * acc)


def ota_packed_ref(
    q: jnp.ndarray,
    scale: jnp.ndarray,
    w: jnp.ndarray,
    *,
    gains: Optional[jnp.ndarray] = None,
    qblock: int = 0,
    packed4: bool = False,
) -> jnp.ndarray:
    """Oracle for the packed-uplink dequant+superpose kernel
    (``ota_fused.ota_packed_2d``).

    q: (K, M) int8/int16/f32 symbols, or (K, M//2) uint8 row-major int4
    nibbles when ``packed4``. scale: (K,)/(K, 1) per-update scales, or
    the (K, n_blocks) blockwise scale matrix — symbol position p
    dequantizes with block p // qblock (``qblock`` = 0 or n_blocks = 1:
    one scale per update, the PR-2 format). w: (K,). ``gains``: optional
    (K,) effective channel gain per row (DESIGN.md §12) — the combining
    coefficient becomes w_k * g_k, multiplied out BEFORE the symbol
    math exactly as the kernel's ``_row_coeff`` does, so kernel and
    oracle stay bit-equal with and without gains (None skips the
    multiply entirely: the legacy program). Returns the (M,) f32
    partial aggregate sum_k w_k [* g_k] * scale_k[block] * q_k. Uses
    the same nibble unpack and per-column scale gather as the kernel
    body so the two are bit-equal per storage group.
    """
    if packed4:
        from repro.kernels.ota_fused import _unpack_nibbles

        q = _unpack_nibbles(q)
    K, M = q.shape
    scales = jnp.asarray(scale, jnp.float32)
    if scales.ndim == 1:
        scales = scales.reshape(K, 1)
    if qblock > 0 and scales.shape[1] > 1:
        bid = jnp.arange(M, dtype=jnp.int32) // qblock
        scale_cols = jnp.take(scales, bid, axis=1, mode="clip")
    else:
        scale_cols = scales  # (K, 1) broadcast
    dq = q.astype(jnp.float32) * scale_cols
    wcol = w.reshape(-1, 1).astype(jnp.float32)
    if gains is not None:
        wcol = wcol * jnp.asarray(gains).reshape(-1, 1).astype(jnp.float32)
    return jnp.sum(dq * wcol, axis=0)


def ota_fold_ref(
    acc: jnp.ndarray,
    q: jnp.ndarray,
    scale: jnp.ndarray,
    w: jnp.ndarray,
    *,
    gains: Optional[jnp.ndarray] = None,
    qblock: int = 0,
    packed4: bool = False,
) -> jnp.ndarray:
    """Oracle for the streaming fold kernel (``ota_fused.ota_fold_2d``).

    acc: the running (M,) f32 superposition state; remaining args as in
    ``ota_packed_ref`` (incl. the optional per-row channel ``gains``).
    Returns acc + sum_k w_k [* g_k] * scale_k[block] * q_k — the
    per-column math of the barrier oracle plus one elementwise add,
    so kernel and oracle are bit-equal and fold(zeros, batch) equals
    ``ota_packed_ref(batch)`` (the persistent-accumulator contract,
    DESIGN.md §11). A wave whose gains are all zero adds exact zeros:
    the accumulator value is unchanged.
    """
    return acc.astype(jnp.float32) + ota_packed_ref(
        q, scale, w, gains=gains, qblock=qblock, packed4=packed4
    )


def ota_aggregate_ref(
    x: jnp.ndarray, w: jnp.ndarray, noise: jnp.ndarray, noise_std: jnp.ndarray
) -> jnp.ndarray:
    """Superpose K client streams: sum_k w_k x_k + noise_std * noise.

    x: (K, M) f32; w: (K,) f32; noise: (M,) f32.
    """
    return (
        jnp.einsum("k,km->m", w.astype(jnp.float32), x.astype(jnp.float32))
        + noise_std * noise
    )


def topk_similarity_ref(
    qm: jnp.ndarray, recs: jnp.ndarray, scales: Optional[jnp.ndarray], n: jnp.ndarray
):
    """Oracle for the fused similarity/top-k kernel
    (``topk_similarity.topk_similarity_2d``) — the identical tile loop
    (dot -> live-count mask -> running ``lax.top_k`` merge) unrolled in
    jnp, so kernel and oracle are bit-equal in interpret mode and share
    the tie contract (descending score, ties by ascending record index).

    qm: (Qp, D) f32; recs: (Np, D) f32 or int8 (Np % TILE_N == 0);
    scales: (Np, D // qblock) f32 for int8 recs, None for f32; n: ()
    live count. Returns (scores (Qp, TOPK_LANES), idx (Qp, TOPK_LANES)).
    """
    from repro.kernels.topk_similarity import TILE_N, TOPK_LANES

    Qp, D = qm.shape
    Np = recs.shape[0]
    assert Np % TILE_N == 0, (Np, TILE_N)
    n = jnp.asarray(n, jnp.int32)
    scores = jnp.full((Qp, TOPK_LANES), -jnp.inf, jnp.float32)
    idx = jnp.zeros((Qp, TOPK_LANES), jnp.int32)
    for i in range(Np // TILE_N):
        rec = recs[i * TILE_N : (i + 1) * TILE_N]
        if scales is not None:
            qblock = D // scales.shape[1]
            rec = rec.astype(jnp.float32) * jnp.repeat(
                scales[i * TILE_N : (i + 1) * TILE_N].astype(jnp.float32),
                qblock,
                axis=1,
            )
        s = jnp.dot(qm, rec.T, preferred_element_type=jnp.float32)
        pos = jax.lax.broadcasted_iota(jnp.int32, (Qp, TILE_N), 1) + i * TILE_N
        s = jnp.where(pos < n, s, -jnp.inf)
        cand_s = jnp.concatenate([scores, s], axis=1)
        cand_i = jnp.concatenate([idx, pos], axis=1)
        v, a = jax.lax.top_k(cand_s, TOPK_LANES)
        scores = v
        idx = jnp.take_along_axis(cand_i, a, axis=1)
    return scores, idx


def qmatmul_ref(x: jnp.ndarray, w_q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """x (M, K) f32/bf16 @ dequant(w_q (K, N) int8, scale (N,)) -> (M, N) f32."""
    w = w_q.astype(jnp.float32) * scale.astype(jnp.float32)[None, :]
    return x.astype(jnp.float32) @ w


def flash_attention_ref(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, causal: bool = True
) -> jnp.ndarray:
    """Naive softmax attention. q: (BH, Sq, D); k/v: (BH, Sk, D)."""
    D = q.shape[-1]
    s = (
        jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32))
        * D**-0.5
    )
    if causal:
        Sq, Sk = q.shape[1], k.shape[1]
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Sk)[None, :]
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
