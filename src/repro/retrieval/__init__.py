from repro.retrieval.arena import ArenaStore
from repro.retrieval.engine import (
    RetrievalEngine,
    brute_force_topk,
    normalize_rows,
    stable_topk,
)
from repro.retrieval.store import ArenaVectorStore
