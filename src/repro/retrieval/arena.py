"""Growable vector arena — the storage layer of the retrieval engine.

One contiguous (capacity, D) buffer with amortized-doubling appends
replaces the legacy store's python list + O(N) re-stack per add/query
cycle. Two storage classes (DESIGN.md §10):

- ``f32``: the plain slab; ``vectors()`` is a zero-copy view.
- ``int8``: blockwise-quantized records — int8 symbols plus a
  (capacity, D // qblock) f32 scale grid, the same symmetric
  amax-over-qmax scale machinery as ``core/quant.quantize_row_sr``
  (round-to-nearest here: storage wants determinism, not the unbiased
  stochastic rounding of the OTA uplink). A D = 256, qblock = 64 record
  costs 256 + 16 bytes vs 1024 f32 — ~3.8x smaller.

Capacity is kept a multiple of ``kernels.topk_similarity.TILE_N`` and
padding rows stay exact zeros (scales 1.0), so the similarity kernel
consumes the raw capacity slab with a traced live count — appends never
recompile the query program. Save/load rides the ckpt layer
(``ckpt/checkpoint.py``): array leaves + a msgpack metadata document.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro.ckpt.checkpoint import load_checkpoint, save_checkpoint
from repro.core.quant import qrange
from repro.kernels.topk_similarity import TILE_N

STORAGE_CLASSES = ("f32", "int8")


def _round_capacity(n: int) -> int:
    cap = TILE_N
    while cap < n:
        cap *= 2
    return cap


class ArenaStore:
    """Append-only growable (capacity, D) vector arena."""

    def __init__(
        self,
        dim: int,
        *,
        storage: str = "f32",
        qblock: int = 64,
        capacity: int = 1024,
    ):
        if storage not in STORAGE_CLASSES:
            raise ValueError(f"unknown storage class {storage!r}")
        if storage == "int8" and dim % qblock:
            raise ValueError(f"qblock {qblock} must divide dim {dim}")
        self.dim = dim
        self.storage = storage
        self.qblock = qblock if storage == "int8" else 0
        self._n = 0
        cap = _round_capacity(capacity)
        if storage == "int8":
            self._data = np.zeros((cap, dim), np.int8)
            self._scales = np.ones((cap, dim // qblock), np.float32)
        else:
            self._data = np.zeros((cap, dim), np.float32)
            self._scales = None

    def __len__(self) -> int:
        return self._n

    @property
    def capacity(self) -> int:
        return self._data.shape[0]

    @property
    def nbytes(self) -> int:
        """Live storage bytes (symbols + scale grid)."""
        out = self._data[: self._n].nbytes
        if self._scales is not None:
            out += self._scales[: self._n].nbytes
        return out

    def shard_rows(self, n_shards: int) -> int:
        """Rows per shard under row sharding (DESIGN.md §15): the
        capacity divided over ``n_shards`` contiguous, TILE_N-aligned
        blocks (rounded up — the mesh path pads the slab to
        ``n_shards * shard_rows`` with the arena's own zero-row/
        unit-scale padding convention, so shard boundaries always land
        on kernel tile boundaries)."""
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        return -(-self.capacity // (n_shards * TILE_N)) * TILE_N

    def shard_bounds(self, n_shards: int) -> Tuple[Tuple[int, int], ...]:
        """Per-shard ``[lo, hi)`` row ranges over the capacity slab —
        contiguous, TILE_N-aligned, clamped to capacity (trailing
        shards may be empty when the slab is smaller than the mesh)."""
        rows = self.shard_rows(n_shards)
        return tuple(
            (min(s * rows, self.capacity), min((s + 1) * rows, self.capacity))
            for s in range(n_shards)
        )

    def shard_nbytes(self, n_shards: int) -> int:
        """Resident bytes of ONE shard's slab slice (symbols + scale
        grid) under row sharding — the per-device memory the mesh
        retrieval path holds, ~1/n_shards of the full slab."""
        per_row = self._data.itemsize * self._data.shape[1]
        if self._scales is not None:
            per_row += self._scales.itemsize * self._scales.shape[1]
        return self.shard_rows(n_shards) * per_row

    def _grow(self, need: int) -> None:
        cap = self.capacity
        if need <= cap:
            return
        while cap < need:
            cap *= 2
        data = np.zeros((cap, self.dim), self._data.dtype)
        data[: self._n] = self._data[: self._n]
        self._data = data
        if self._scales is not None:
            scales = np.ones((cap, self._scales.shape[1]), np.float32)
            scales[: self._n] = self._scales[: self._n]
            self._scales = scales

    def _quantize(self, mat: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Blockwise symmetric int8 RTN on the core/quant scale grid."""
        qmax = float(qrange(8))
        b, nb = mat.shape[0], self.dim // self.qblock
        blocks = mat.reshape(b, nb, self.qblock)
        amax = np.abs(blocks).max(axis=2)
        scales = (np.maximum(amax, 1e-12) / qmax).astype(np.float32)
        q = np.clip(np.rint(blocks / scales[..., None]), -qmax, qmax)
        return q.astype(np.int8).reshape(b, self.dim), scales

    def add(self, vec: np.ndarray) -> int:
        """Append one (D,) vector; returns its record index."""
        return int(self.add_batch(np.asarray(vec, np.float32)[None])[0])

    def add_batch(self, mat: np.ndarray) -> np.ndarray:
        """Append a (B, D) batch; returns the (B,) record indices."""
        mat = np.asarray(mat, np.float32)
        if mat.ndim != 2 or mat.shape[1] != self.dim:
            raise ValueError(f"expected (B, {self.dim}), got {mat.shape}")
        b = mat.shape[0]
        self._grow(self._n + b)
        lo = self._n
        if self.storage == "int8":
            q, scales = self._quantize(mat)
            self._data[lo : lo + b] = q
            self._scales[lo : lo + b] = scales
        else:
            self._data[lo : lo + b] = mat
        self._n += b
        return np.arange(lo, lo + b, dtype=np.int32)

    def dequantize_rows(self, lo: int, hi: int) -> np.ndarray:
        """Rows [lo, hi) as f32 — a view for f32 storage, a dequantized
        copy for int8."""
        if self.storage == "f32":
            return self._data[lo:hi]
        q = self._data[lo:hi].astype(np.float32)
        return q * np.repeat(self._scales[lo:hi], self.qblock, axis=1)

    def vectors(self) -> np.ndarray:
        """The live (n, D) f32 slab (dequantized for int8 storage)."""
        return self.dequantize_rows(0, self._n)

    def raw(self) -> Tuple[np.ndarray, Optional[np.ndarray]]:
        """The full capacity buffers (data, scales-or-None) the kernel
        path consumes alongside the traced live count ``len(self)``."""
        return self._data, self._scales

    # -- persistence (ckpt layer) ------------------------------------------

    def save(self, path: str, meta: Optional[Dict[str, Any]] = None) -> None:
        tree = {"data": self._data[: self._n].copy()}
        if self._scales is not None:
            tree["scales"] = self._scales[: self._n].copy()
        save_checkpoint(
            path,
            tree,
            meta={
                "kind": "arena_store",
                "dim": self.dim,
                "storage": self.storage,
                "qblock": self.qblock,
                "n": self._n,
                "extra": meta or {},
            },
        )

    @classmethod
    def load(cls, path: str) -> Tuple["ArenaStore", Dict[str, Any]]:
        """Returns (store, extra-meta dict passed to ``save``)."""
        tree, meta = load_checkpoint(path)
        if meta.get("kind") != "arena_store":
            raise ValueError(f"{path} is not an arena checkpoint")
        store = cls(
            meta["dim"],
            storage=meta["storage"],
            qblock=meta["qblock"] or 64,
            capacity=max(int(meta["n"]), 1),
        )
        n = int(meta["n"])
        store._data[:n] = np.asarray(tree["data"])
        if store._scales is not None:
            store._scales[:n] = np.asarray(tree["scales"])
        store._n = n
        return store, meta["extra"]
