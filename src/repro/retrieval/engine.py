"""Batched top-k retrieval over an ``ArenaStore``.

One selection contract everywhere (DESIGN.md §10): descending score,
equal scores by ascending record index. Three implementations share it:

- ``brute_force_topk`` — the O(N log N) stable-argsort specification the
  equivalence tests anchor on;
- the numpy engine path — one GEMM over the live slab plus
  ``stable_topk`` (argpartition + tie repair), the CPU perf path. The
  GEMM is the *same* ``queries @ slab.T`` call the brute force makes, so
  on f32 stores the engine's top-k equals the brute-force results
  exactly, scores included;
- the Pallas kernel / jnp-oracle path (``kernels.ops.topk_cosine``) —
  the TPU path, streamed over record tiles with a running in-kernel
  top-k, bit-equal to its oracle.

As with the OTA data plane, the kernel runs by default only on TPU
(interpret-mode Pallas is a correctness tool); off-TPU the engine uses
the numpy path unless ``use_kernel`` forces otherwise.

Mesh sharding (DESIGN.md §15): construct the engine with ``mesh`` (a
``data``-axis device mesh) to place the slab rows across devices — the
per-shard fused top-k plus the exact lane merge is bit-identical to the
unsharded jax path, scores and indices. ``n_shards`` instead shards on
the host (per-shard GEMM + ``merge_candidates``), bounding peak f32
bytes at ~1/n_shards under the same tie contract.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import numpy as np

from repro import obs
from repro.retrieval.arena import ArenaStore

# int8 stores dequantize in row chunks of this size on the numpy path so
# a large arena never materialises its full f32 slab
CHUNK_ROWS = 1 << 15


@functools.lru_cache(maxsize=None)
def _default_use_kernel() -> bool:
    """Kernel path on TPU only, as in core/ota.py. Memoized: the first
    ``jax.devices()`` call initializes the backend (~0.1s) and must not
    recur per query."""
    import jax

    return jax.devices()[0].platform == "tpu"


def stable_topk(scores: np.ndarray, k: int) -> Tuple[np.ndarray, np.ndarray]:
    """Exact (Q, k) top-k of a (Q, N) score matrix under the tie contract.

    A partition's boundary splits tied scores arbitrarily, so only the
    kth-largest *value* is taken from ``np.partition`` (cheaper than
    argpartition: no index payload to permute); candidates are then
    re-gathered from that threshold upward and stable-sorted by
    (-score, index) — duplicates always resolve to the lowest record
    indices, matching ``brute_force_topk`` and the kernel's running
    ``lax.top_k`` merge.
    """
    q, n = scores.shape
    k = min(k, n)
    if k == n:
        order = np.argsort(-scores, axis=1, kind="stable")[:, :k]
    else:
        thresh = np.partition(scores, n - k, axis=1)[:, n - k]
        order = np.empty((q, k), np.int64)
        for r in range(q):
            row = scores[r]
            cand = np.nonzero(row >= thresh[r])[0]
            order[r] = cand[np.lexsort((cand, -row[cand]))][:k]
    return np.take_along_axis(scores, order, axis=1), order.astype(np.int32)


def brute_force_topk(
    vectors: np.ndarray, queries: np.ndarray, k: int
) -> Tuple[np.ndarray, np.ndarray]:
    """The specification: full scores, full stable argsort, slice k."""
    scores = queries @ vectors.T
    k = min(k, vectors.shape[0])
    order = np.argsort(-scores, axis=1, kind="stable")[:, :k]
    return np.take_along_axis(scores, order, axis=1), order.astype(np.int32)


def merge_candidates(cand_s, cand_i, k: int) -> Tuple[np.ndarray, np.ndarray]:
    """Exact k-way merge of per-chunk top-k candidate lists under the
    tie contract: any global top-k member is top-k within its chunk, so
    re-sorting the concatenated candidates by (-score, ascending global
    index) — ``np.lexsort``'s last-key-primary order — reproduces the
    global selection exactly. Shared by the int8 chunked path and the
    host-sharded path (DESIGN.md §15)."""
    s_all = np.concatenate(cand_s, axis=1)
    i_all = np.concatenate(cand_i, axis=1)
    q = s_all.shape[0]
    k = min(k, s_all.shape[1])
    scores = np.empty((q, k), np.float32)
    idx = np.empty((q, k), np.int32)
    for r in range(q):
        order = np.lexsort((i_all[r], -s_all[r]))[:k]
        scores[r] = s_all[r, order]
        idx[r] = i_all[r, order]
    return scores, idx


def normalize_rows(mat: np.ndarray) -> np.ndarray:
    """Unit-normalize rows; all-zero rows stay zero (the zero-norm query
    guard — downstream similarity filters drop their sim-0 hits)."""
    mat = np.asarray(mat, np.float32)
    norms = np.linalg.norm(mat, axis=1, keepdims=True)
    return np.where(norms > 0, mat / np.maximum(norms, 1e-30), mat)


class RetrievalEngine:
    """Batched cosine top-k queries against one arena."""

    def __init__(
        self,
        store: ArenaStore,
        *,
        use_kernel: Optional[bool] = None,
        mesh=None,
        n_shards: int = 0,
    ):
        self.store = store
        self.use_kernel = use_kernel
        # mesh-sharded data plane (DESIGN.md §15): with ``mesh`` (a
        # ``data``-axis device mesh, launch.mesh.make_data_mesh) the
        # slab rows place across devices and queries run the sharded
        # fused top-k — bit-identical to the unsharded jax path.
        # ``n_shards`` > 1 instead shards on the host: per-shard GEMM +
        # exact merge (the int8 chunked machinery over shard bounds) —
        # ~1/n_shards peak f32 bytes, same tie contract.
        self.mesh = mesh
        self.n_shards = int(n_shards)
        # device copies of the arena slab for the kernel path, keyed on
        # (buffer identity, live count): appends (new n) and grows (new
        # buffer) invalidate; repeated queries between appends reuse the
        # upload instead of re-transferring the whole capacity slab
        self._dev_cache = None

    def topk(self, queries: np.ndarray, k: int) -> Tuple[np.ndarray, np.ndarray]:
        """(Q, D) query batch -> (scores (Q, k'), idx (Q, k')) with
        k' = min(k, len(store)); empty stores return zero-width arrays."""
        queries = np.ascontiguousarray(np.asarray(queries, np.float32))
        if queries.ndim != 2 or queries.shape[1] != self.store.dim:
            raise ValueError(f"expected (Q, {self.store.dim}), got {queries.shape}")
        q = queries.shape[0]
        n = len(self.store)
        k = min(k, n)
        if n == 0 or k <= 0 or q == 0:
            return np.zeros((q, 0), np.float32), np.zeros((q, 0), np.int32)
        with obs.span("retrieval.query", q=q, k=k, rows=n):
            obs.metrics.inc("retrieval.queries", q)
            obs.metrics.inc("retrieval.query_rows", q * n)
            use_kernel = self.use_kernel
            if use_kernel is None:
                use_kernel = _default_use_kernel()
            from repro.kernels.topk_similarity import TOPK_LANES

            if self.mesh is not None and k <= TOPK_LANES:
                return self._topk_jax_sharded(queries, k, use_kernel)
            if use_kernel and k <= TOPK_LANES:
                return self._topk_jax(queries, k)
            if self.n_shards > 1:
                return self._topk_numpy_sharded(queries, k)
            return self._topk_numpy(queries, k)

    def _topk_numpy(self, queries, k):
        store = self.store
        n = len(store)
        if store.storage == "f32":
            scores = queries @ store.vectors().T
            return stable_topk(scores, k)
        # int8: per-chunk candidates, then one stable merge — any global
        # top-k member is top-k within its chunk, so the merge is exact
        cand_s, cand_i = [], []
        for lo in range(0, n, CHUNK_ROWS):
            hi = min(lo + CHUNK_ROWS, n)
            s, i = stable_topk(queries @ store.dequantize_rows(lo, hi).T, k)
            cand_s.append(s)
            cand_i.append(i + lo)
        return merge_candidates(cand_s, cand_i, k)

    def _topk_numpy_sharded(self, queries, k):
        """Host-sharded numpy path: per-shard GEMM + top-k over the
        arena's shard bounds, then the exact merge. The selection obeys
        the tie contract against the per-shard GEMM scores; note BLAS
        may pick different microkernels per GEMM shape, so last-ulp
        score agreement with the single-GEMM path is not guaranteed —
        the bitwise-locked multi-device lane is the jax path
        (``_topk_jax_sharded``), see DESIGN.md §15."""
        store, n = self.store, len(self.store)
        cand_s, cand_i = [], []
        with obs.span("shard_merge", shards=self.n_shards, k=k):
            for lo, hi in store.shard_bounds(self.n_shards):
                hi = min(hi, n)
                if hi <= lo:
                    continue
                s, i = stable_topk(queries @ store.dequantize_rows(lo, hi).T, k)
                cand_s.append(s)
                cand_i.append(i + lo)
            return merge_candidates(cand_s, cand_i, k)

    def _topk_jax_sharded(self, queries, k, use_kernel: bool):
        """Mesh-sharded fused top-k (DESIGN.md §15): slab rows place
        across the mesh's ``data`` axis, each shard runs the fused tile
        loop locally, and the lane merge reproduces the unsharded
        selection bit-identically (``kernels.ops.topk_cosine_sharded``).
        The slab is padded to shards * shard_rows with the arena's own
        zero-row/unit-scale convention before upload."""
        import jax.numpy as jnp

        from repro.kernels.ops import topk_cosine_sharded

        store = self.store
        n_shards = self.mesh.shape["data"]
        data, scales = store.raw()
        pad = n_shards * store.shard_rows(n_shards) - data.shape[0]
        cache = self._dev_cache
        if cache is None or cache[0] is not data or cache[1] != len(store):
            dd, ss = data, scales
            if pad:
                dd = np.concatenate(
                    [dd, np.zeros((pad, dd.shape[1]), dd.dtype)]
                )
                if ss is not None:
                    ss = np.concatenate(
                        [ss, np.ones((pad, ss.shape[1]), np.float32)]
                    )
            cache = (
                data,
                len(store),
                jnp.asarray(dd),
                None if ss is None else jnp.asarray(ss),
            )
            self._dev_cache = cache
        with obs.span("shard_merge", shards=n_shards, k=k):
            s, i = topk_cosine_sharded(
                jnp.asarray(queries),
                cache[2],
                cache[3],
                jnp.int32(len(store)),
                k=k,
                mesh=self.mesh,
                use_kernel=use_kernel,
            )
            return np.asarray(s), np.asarray(i)

    def _topk_jax(self, queries, k):
        import jax.numpy as jnp

        from repro.kernels.ops import topk_cosine

        data, scales = self.store.raw()
        cache = self._dev_cache
        if cache is None or cache[0] is not data or cache[1] != len(self.store):
            cache = (
                data,
                len(self.store),
                jnp.asarray(data),
                None if scales is None else jnp.asarray(scales),
            )
            self._dev_cache = cache
        dev_data, dev_scales = cache[2], cache[3]
        s, i = topk_cosine(
            jnp.asarray(queries),
            dev_data,
            dev_scales,
            jnp.int32(len(self.store)),
            k=k,
        )
        return np.asarray(s), np.asarray(i)
