"""Arena-backed vector store with per-record payloads.

The record layer the RAG databases (``core/profiling/ragdb.py``) ride:
vectors live in one ``ArenaStore`` slab (f32 or the int8 blockwise
storage class), payload records in a parallel python list, and every
query goes through the batched ``RetrievalEngine`` — one engine call per
cohort instead of one numpy scan per client (DESIGN.md §10).

The store is strictly append-only: feedback writeback only ever appends
(vector, record) pairs, so record indices are stable for the lifetime of
the store and a reload resumes appending where the save left off.
Persistence rides the arena's ckpt-layer format with records serialized
into the metadata document via the ``to_doc``/``from_doc`` codec hooks.
"""

from __future__ import annotations

from typing import Any, Callable, List, Optional, Tuple

import numpy as np

from repro.retrieval.arena import ArenaStore
from repro.retrieval.engine import RetrievalEngine

Hit = Tuple[float, Any]  # (similarity, record)


class ArenaVectorStore:
    """Vectors in an arena + opaque payload records, batched top-k."""

    def __init__(
        self,
        dim: int,
        *,
        storage: str = "f32",
        qblock: int = 64,
        use_kernel: Optional[bool] = None,
        to_doc: Optional[Callable[[Any], Any]] = None,
        from_doc: Optional[Callable[[Any], Any]] = None,
    ):
        self.arena = ArenaStore(dim, storage=storage, qblock=qblock)
        self.engine = RetrievalEngine(self.arena, use_kernel=use_kernel)
        self.records: List[Any] = []
        self._to_doc = to_doc or (lambda r: r)
        self._from_doc = from_doc or (lambda d: d)

    def __len__(self) -> int:
        return len(self.records)

    def add_vec(self, vec: np.ndarray, record: Any) -> int:
        """Append one (vector, record) pair; returns the record index."""
        idx = self.arena.add(vec)
        self.records.append(record)
        return idx

    def query_vec(self, vec: np.ndarray, k: int = 8) -> List[Hit]:
        """Top-k hits for one query vector."""
        return self.query_batch(np.asarray(vec, np.float32)[None], k)[0]

    def query_batch(self, queries: np.ndarray, k: int = 8) -> List[List[Hit]]:
        """One engine call for a (Q, D) query batch -> per-query hit
        lists, each ordered by the engine's tie contract."""
        scores, idx = self.engine.topk(queries, k)
        return [
            [(float(s), self.records[j]) for s, j in zip(srow, irow)]
            for srow, irow in zip(scores, idx)
        ]

    # -- persistence --------------------------------------------------------

    def save(self, path: str) -> None:
        self.arena.save(path, meta={"records": [self._to_doc(r) for r in self.records]})

    def restore(self, path: str) -> None:
        """Replace this store's contents from a ``save`` checkpoint (the
        codec hooks and kernel preference of this instance are kept)."""
        arena, extra = ArenaStore.load(path)
        if arena.dim != self.arena.dim or arena.storage != self.arena.storage:
            raise ValueError(
                f"checkpoint is ({arena.dim}, {arena.storage}), store is "
                f"({self.arena.dim}, {self.arena.storage})"
            )
        self.arena = arena
        self.engine = RetrievalEngine(arena, use_kernel=self.engine.use_kernel)
        self.records = [self._from_doc(d) for d in extra["records"]]
