"""arctic-480b — 128-expert top-2 MoE with dense residual MLP.
[hf:Snowflake/snowflake-arctic-base]
"""
from repro.configs.base import ArchConfig, register_arch


@register_arch("arctic-480b")
def arctic_480b() -> ArchConfig:
    return ArchConfig(
        name="arctic-480b",
        family="moe",
        n_layers=35,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        d_ff=4864,           # dense-residual MLP width
        moe_d_ff=4864,       # expert FFN width
        n_experts=128,
        experts_per_token=2,
        dense_residual=True,  # dense MLP in parallel with the MoE branch
        vocab_size=32_000,
        source="hf:Snowflake/snowflake-arctic-base",
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        remat=True,
    )
