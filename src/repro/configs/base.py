"""Config system: architecture, input shapes, FL experiment, precision levels.

Everything is a frozen dataclass so configs hash/compare cleanly and can be
used as jit static arguments. Arch configs for the 10 assigned architectures
live in sibling modules (one file per arch) and register themselves in
``ARCH_REGISTRY`` via :func:`register_arch`.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Dict, Tuple

from repro.core.packing import QUANT_BLOCK

# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ArchConfig:
    """Static description of a transformer-family architecture."""

    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    source: str = ""  # citation per the assignment table

    # Attention flavour flags
    head_dim: int = 0  # 0 -> d_model // n_heads
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    mrope: bool = False  # sectioned multimodal RoPE (qwen2-vl)
    mrope_sections: Tuple[int, ...] = (16, 24, 24)
    tie_embeddings: bool = False
    norm_eps: float = 1e-5

    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    dense_residual: bool = False  # arctic: dense FFN in parallel with MoE
    router_aux_coef: float = 0.01

    # SSM (mamba1/mamba2)
    ssm_state: int = 0
    ssm_conv: int = 4
    d_inner: int = 0  # 0 -> 2 * d_model
    ssm_heads: int = 0  # mamba2 heads; 0 -> d_inner // 64
    dt_rank: int = 0  # mamba1 dt projection rank; 0 -> d_model // 16

    # Hybrid (zamba2): a shared attention block applied every `attn_every`
    # SSM layers (weights shared across applications, per the Zamba design).
    attn_every: int = 0

    # Encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 1500  # whisper: 30 s of audio at 50 Hz after conv

    # Modality frontend stub ("none" | "audio" | "vision")
    frontend: str = "none"
    frontend_dim: int = 0  # embedding dim delivered by the stub

    # Decode
    window: int = 8192  # sliding-window KV cache size for long-context decode

    # Numerics
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    remat: bool = False

    # Lowering controls (dry-run cost calibration; see launch/dryrun.py).
    # XLA cost_analysis counts scan bodies ONCE — unrolled variants give
    # true per-layer HLO costs which the dry-run extrapolates to full depth.
    unroll_layers: bool = False
    unroll_attn: bool = False
    attn_chunk: int = 1024
    loss_chunk: int = 512
    # Use the Pallas flash-attention kernel for full-sequence causal
    # attention (TPU; interpret-mode on CPU — correct but slow, so tests
    # opt in explicitly). Falls back to the jnp chunked path for windowed
    # or non-causal attention.
    use_flash_kernel: bool = False

    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def resolved_d_inner(self) -> int:
        return self.d_inner or 2 * self.d_model

    def resolved_ssm_heads(self) -> int:
        return self.ssm_heads or max(1, self.resolved_d_inner() // 64)

    def resolved_dt_rank(self) -> int:
        return self.dt_rank or max(1, self.d_model // 16)

    def with_(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: 2 layers, d_model<=256, <=4 experts."""
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4)
        n_kv = min(self.n_kv_heads, n_heads) if self.n_kv_heads else 0
        kw: Dict[str, Any] = dict(
            name=self.name + "-reduced",
            n_layers=2,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=max(1, n_kv),
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            head_dim=0,
            d_inner=0,
            dt_rank=0,
            ssm_heads=0,
            window=64,
            remat=False,
            param_dtype="float32",
            compute_dtype="float32",
        )
        if self.n_experts:
            kw.update(
                n_experts=min(self.n_experts, 4),
                experts_per_token=min(self.experts_per_token, 2),
                moe_d_ff=min(self.moe_d_ff or self.d_ff, 256),
            )
        if self.attn_every:
            kw.update(attn_every=1, n_layers=2)
        if self.encoder_layers:
            kw.update(encoder_layers=2, encoder_seq=32)
        if self.frontend != "none":
            kw.update(frontend_dim=d_model)
        if self.mrope:
            # rescale M-RoPE sections to the reduced head_dim
            half = (d_model // n_heads) // 2
            t = max(1, half // 4)
            rest = (half - t) // 2
            kw.update(mrope_sections=(t, rest, half - t - rest))
        return self.with_(**kw)


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Precision levels (the paper's quantization control variable)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PrecisionLevel:
    """One selectable client precision level.

    ``bits`` drives both quantization and the analytic energy model:
    compute energy per MAC scales ~bits^2 (CMOS multiplier), comms energy
    scales ~bits. ``rel_energy`` is relative to the 32-bit level, matching
    the paper's "Relative Energy Cost" metric.
    """

    bits: int

    @property
    def rel_energy(self) -> float:
        # Sub-quadratic in practice: memory traffic, control overheads and
        # fixed radio cost flatten the CMOS bits^2 MAC curve on real devices.
        compute = (self.bits / 32.0) ** 0.9
        overhead = (self.bits / 32.0) ** 0.45
        return 0.55 * compute + 0.45 * overhead

    @property
    def rel_latency(self) -> float:
        # Lower precision -> faster MACs and smaller transfers.
        return 0.5 * (self.bits / 32.0) + 0.5 * (self.bits / 32.0) ** 0.5

    @property
    def rel_accuracy(self) -> float:
        # PTQ accuracy-retention prior (quiet conditions), per bit width.
        return {4: 0.75, 8: 0.93, 16: 0.99, 32: 1.0}[self.bits]

    @property
    def noise_sensitivity(self) -> float:
        # additional accuracy degradation per unit ambient noise (quantized
        # ASR is less noise-robust at low precision).
        return {4: 0.35, 8: 0.15, 16: 0.05, 32: 0.02}[self.bits]


PRECISION_LEVELS: Tuple[PrecisionLevel, ...] = tuple(
    PrecisionLevel(b) for b in (4, 8, 16, 32)
)
BITS_TO_LEVEL = {p.bits: p for p in PRECISION_LEVELS}


# ---------------------------------------------------------------------------
# FL experiment config (paper §IV)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class FLConfig:
    n_clients: int = 100
    clients_per_round: int = 20
    n_rounds: int = 100
    local_steps: int = 4
    local_batch: int = 8
    lr: float = 5e-4
    strategy: str = "fedavg"  # fedavg | class_equal | majority_centric
    planner: str = "rag"  # rag | unified | rag_energy
    snr_db: float = 20.0
    # uplink quantization block: symbols per wire scale (blockwise
    # scales, DESIGN.md §6). 0 = one per-update scale (the original
    # per-row wire format).
    quant_block: int = QUANT_BLOCK
    seed: int = 0
    # physical OTA channel (core/channel.py, DESIGN.md §12). "ideal" is
    # the legacy path (participation coin-flip + AWGN only, bit-identical
    # to pre-channel runs); "fading" draws per-client Rayleigh gains with
    # truncated channel inversion under the transmit power budget.
    channel_model: str = "ideal"  # ideal | fading
    fade_threshold: float = 0.1   # |h|^2 truncation threshold
    tx_power_budget: float = 100.0  # per-client max transmit power P
    pathloss_spread_db: float = 0.0  # log-normal shadowing std (dB)
    # compressed downlink broadcast (core/wire.py, DESIGN.md §13):
    # bits >= 32 is the f32 passthrough — byte-identical to the legacy
    # uncompressed broadcast; below that the server quantizes the round's
    # global delta once (blockwise scales every ``downlink_block``
    # symbols) and every client reconstructs bit-identical params.
    downlink_bits: int = 32
    downlink_block: int = QUANT_BLOCK
    # mesh-sharded data planes (DESIGN.md §15): shard the OTA fold's
    # symbol axis over the ``data`` axis of a 1-D device mesh
    # (launch/mesh.make_data_mesh). 0/1 = the single-host path; > 1
    # needs that many visible jax devices and stays bit-identical to
    # the unsharded aggregation.
    mesh_data_shards: int = 0
    # robustness options
    dropout_prob: float = 0.0   # straggler/device dropout per round
    fedprox_mu: float = 0.0     # proximal term pulling local weights to global
    server_momentum: float = 0.0  # FedAvgM velocity on the aggregated update
    # store the FedAvgM velocity bf16 (0.5x resident bytes; DESIGN.md §13)
    quantize_server_state: bool = False
    # paper Table II category mixture
    categories: Tuple[str, ...] = (
        "entertainment",
        "smart_home",
        "general_query",
        "personal_request",
    )
    category_probs: Tuple[float, ...] = (0.327, 0.160, 0.319, 0.194)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCH_REGISTRY: Dict[str, Callable[[], ArchConfig]] = {}


def register_arch(name: str):
    def deco(fn: Callable[[], ArchConfig]):
        ARCH_REGISTRY[name] = fn
        return fn

    return deco


def get_arch(name: str) -> ArchConfig:
    """Look up an architecture config by id (importing config modules)."""
    import repro.configs.all_archs  # noqa: F401  (side-effect registration)

    if name not in ARCH_REGISTRY:
        raise KeyError(
            f"unknown arch {name!r}; known: {sorted(ARCH_REGISTRY)}"
        )
    return ARCH_REGISTRY[name]()


def list_archs() -> Tuple[str, ...]:
    import repro.configs.all_archs  # noqa: F401

    return tuple(sorted(ARCH_REGISTRY))
