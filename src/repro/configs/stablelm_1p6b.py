"""stablelm-1.6b — dense. [hf:stabilityai/stablelm-2-1_6b]"""
from repro.configs.base import ArchConfig, register_arch


@register_arch("stablelm-1.6b")
def stablelm_1p6b() -> ArchConfig:
    return ArchConfig(
        name="stablelm-1.6b",
        family="dense",
        n_layers=24,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_ff=5632,
        vocab_size=100_352,
        source="hf:stabilityai/stablelm-2-1_6b",
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        remat=True,
    )
