from repro.configs.base import (  # noqa: F401
    ARCH_REGISTRY,
    ArchConfig,
    BITS_TO_LEVEL,
    FLConfig,
    INPUT_SHAPES,
    InputShape,
    PRECISION_LEVELS,
    PrecisionLevel,
    get_arch,
    list_archs,
    register_arch,
)
