"""DeepSpeech2-style ASR model used by the paper's FL experiment (§IV-A).

Paper: Amodei et al., "Deep Speech 2" [arXiv:1512.02595]; the paper trains
it federated on Common Voice filtered to 4 smart-assistant categories.
We keep the conv + bidirectional-RNN + CTC structure at a size suitable
for 100-client CPU simulation. Registered as an arch so the generic
launch/driver tooling can select it with --arch deepspeech2.
"""
from repro.configs.base import ArchConfig, register_arch


@register_arch("deepspeech2")
def deepspeech2_paper() -> ArchConfig:
    return ArchConfig(
        name="deepspeech2",
        family="ds2",
        n_layers=3,          # bi-GRU layers
        d_model=256,         # RNN hidden size
        n_heads=1,
        n_kv_heads=1,
        d_ff=0,
        vocab_size=64,       # char-level vocab for synthetic commands
        frontend="audio",
        frontend_dim=80,     # mel-feature dim delivered by the (synthetic) frontend
        source="arXiv:1512.02595",
    )
