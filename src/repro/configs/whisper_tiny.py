"""whisper-tiny — enc-dec audio, conv frontend stubbed. [arXiv:2212.04356]

The mel-spectrogram + conv feature extractor is a STUB per the assignment
carve-out: ``input_specs()`` delivers precomputed frame embeddings of shape
(batch, encoder_seq, d_model). Encoder (bidirectional self-attn, sinusoidal
positions) and decoder (causal self-attn + cross-attn) are fully implemented.
"""
from repro.configs.base import ArchConfig, register_arch


@register_arch("whisper-tiny")
def whisper_tiny() -> ArchConfig:
    return ArchConfig(
        name="whisper-tiny",
        family="audio",
        n_layers=4,           # decoder layers
        encoder_layers=4,
        encoder_seq=1500,
        d_model=384,
        n_heads=6,
        n_kv_heads=6,
        d_ff=1536,
        vocab_size=51_865,
        rope_theta=0.0,       # whisper uses learned/sinusoidal positions
        frontend="audio",
        frontend_dim=384,
        source="arXiv:2212.04356",
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
    )
