"""falcon-mamba-7b — attention-free Mamba-1 SSM. [arXiv:2410.05355]"""
from repro.configs.base import ArchConfig, register_arch


@register_arch("falcon-mamba-7b")
def falcon_mamba_7b() -> ArchConfig:
    return ArchConfig(
        name="falcon-mamba-7b",
        family="ssm",
        n_layers=64,
        d_model=4096,
        n_heads=1,          # attention-free
        n_kv_heads=1,
        d_ff=0,             # no MLP — mamba block is the whole layer
        vocab_size=65_024,
        ssm_state=16,
        ssm_conv=4,
        source="arXiv:2410.05355",
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        remat=True,
    )
