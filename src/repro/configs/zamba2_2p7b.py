"""zamba2-2.7b — Mamba2 backbone + shared attention blocks. [arXiv:2411.15242]"""
from repro.configs.base import ArchConfig, register_arch


@register_arch("zamba2-2.7b")
def zamba2_2p7b() -> ArchConfig:
    return ArchConfig(
        name="zamba2-2.7b",
        family="hybrid",
        n_layers=54,
        d_model=2560,
        n_heads=32,
        n_kv_heads=32,
        d_ff=10240,
        vocab_size=32_000,
        ssm_state=64,
        ssm_conv=4,
        attn_every=6,  # shared attention block applied every 6 mamba2 layers
        source="arXiv:2411.15242",
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        remat=True,
    )
