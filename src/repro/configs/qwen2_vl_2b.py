"""qwen2-vl-2b — VLM backbone, M-RoPE, GQA kv=2. [arXiv:2409.12191]

The ViT vision encoder + projector is a STUB per the assignment carve-out:
``input_specs()`` delivers precomputed patch embeddings of shape
(batch, n_patches, d_model); this config describes the language decoder
that consumes them (patch embeddings are prepended to token embeddings).
"""
from repro.configs.base import ArchConfig, register_arch


@register_arch("qwen2-vl-2b")
def qwen2_vl_2b() -> ArchConfig:
    return ArchConfig(
        name="qwen2-vl-2b",
        family="vlm",
        n_layers=28,
        d_model=1536,
        n_heads=12,
        n_kv_heads=2,
        d_ff=8960,
        vocab_size=151_936,
        qkv_bias=True,
        mrope=True,
        mrope_sections=(16, 24, 24),  # temporal / height / width RoPE split
        rope_theta=1_000_000.0,
        frontend="vision",
        frontend_dim=1536,
        source="arXiv:2409.12191",
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        remat=True,
    )
