"""qwen1.5-110b — dense, QKV bias. [hf:Qwen/Qwen1.5-0.5B]"""
from repro.configs.base import ArchConfig, register_arch


@register_arch("qwen1.5-110b")
def qwen1p5_110b() -> ArchConfig:
    return ArchConfig(
        name="qwen1.5-110b",
        family="dense",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=49152,
        vocab_size=152_064,
        qkv_bias=True,
        source="hf:Qwen/Qwen1.5-0.5B",
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        remat=True,
    )
