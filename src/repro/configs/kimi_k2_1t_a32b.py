"""kimi-k2-1t-a32b — trillion-param MoE (paper-table). [arXiv:2501.kimi2]"""
from repro.configs.base import ArchConfig, register_arch


@register_arch("kimi-k2-1t-a32b")
def kimi_k2_1t_a32b() -> ArchConfig:
    return ArchConfig(
        name="kimi-k2-1t-a32b",
        family="moe",
        n_layers=61,
        d_model=7168,
        n_heads=64,
        n_kv_heads=8,
        d_ff=2048,          # expert FFN width (MoE 384e top-8)
        moe_d_ff=2048,
        n_experts=384,
        experts_per_token=8,
        vocab_size=163_840,
        source="arXiv:2501.kimi2",
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        remat=True,
    )
