"""deepseek-67b — dense llama-arch, GQA kv=8. [arXiv:2401.02954]"""
from repro.configs.base import ArchConfig, register_arch


@register_arch("deepseek-67b")
def deepseek_67b() -> ArchConfig:
    return ArchConfig(
        name="deepseek-67b",
        family="dense",
        n_layers=95,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=22016,
        vocab_size=102_400,
        source="arXiv:2401.02954",
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        remat=True,
    )
