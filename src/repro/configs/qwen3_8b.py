"""qwen3-8b — dense, GQA kv=8, qk-norm. [hf:Qwen/Qwen3-8B]"""
from repro.configs.base import ArchConfig, register_arch


@register_arch("qwen3-8b")
def qwen3_8b() -> ArchConfig:
    return ArchConfig(
        name="qwen3-8b",
        family="dense",
        n_layers=36,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=12288,
        vocab_size=151_936,
        qk_norm=True,
        rope_theta=1_000_000.0,
        source="hf:Qwen/Qwen3-8B",
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        remat=True,
    )
