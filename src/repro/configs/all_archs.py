"""Import side-effect module: registers every assigned architecture."""
# The 10 assigned architectures
import repro.configs.kimi_k2_1t_a32b  # noqa: F401
import repro.configs.zamba2_2p7b  # noqa: F401
import repro.configs.stablelm_1p6b  # noqa: F401
import repro.configs.qwen3_8b  # noqa: F401
import repro.configs.qwen2_vl_2b  # noqa: F401
import repro.configs.deepseek_67b  # noqa: F401
import repro.configs.whisper_tiny  # noqa: F401
import repro.configs.qwen1p5_110b  # noqa: F401
import repro.configs.falcon_mamba_7b  # noqa: F401
import repro.configs.arctic_480b  # noqa: F401

# The paper's own model
import repro.configs.deepspeech2_paper  # noqa: F401

ASSIGNED_ARCHS = (
    "kimi-k2-1t-a32b",
    "zamba2-2.7b",
    "stablelm-1.6b",
    "qwen3-8b",
    "qwen2-vl-2b",
    "deepseek-67b",
    "whisper-tiny",
    "qwen1.5-110b",
    "falcon-mamba-7b",
    "arctic-480b",
)
