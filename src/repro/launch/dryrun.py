import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# NOTE: the two lines above MUST run before any jax import (jax locks the
# device count at first init). Do not move them.

"""Multi-pod dry-run: AOT lower + compile every (arch x input-shape x mesh)
combination on the production mesh, and extract the roofline terms.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all            # 40 baselines
  PYTHONPATH=src python -m repro.launch.dryrun --all --multipod # 512-chip pass

Each run appends a JSON record to --out (default benchmarks/dryrun_results.json):
bytes-per-device, HLO FLOPs, HLO bytes accessed, per-collective byte counts
parsed from the compiled HLO, compile wall time, and the analytic model
FLOPs — everything EXPERIMENTS.md §Dry-run / §Roofline reads.
"""
import argparse
import json
import re
import time
from typing import Any, Dict, Optional

import jax

from repro.configs.base import INPUT_SHAPES, ArchConfig, InputShape, get_arch
from repro.launch import sharding as shd
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16, make_production_mesh
from repro.launch.steps import (make_decode_step, make_prefill_step,
                                make_train_step, train_state_shapes)
from repro.models.registry import build_model
from repro.optim import adamw

# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(
    r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|u32"
    r"|s16|u16|s8|u8|pred|c64|c128)\[([0-9,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output-shape bytes of every collective op in the HLO module."""
    out = {c: 0 for c in _COLLECTIVES}
    counts = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.lstrip()
        # HLO: `%name = TYPE[SHAPE] all-gather(...)` or fusion-wrapped
        m = None
        for c in _COLLECTIVES:
            if (f" {c}(" in stripped or f"={c}(" in stripped
                    or stripped.startswith(c + "(")):
                m = c
                break
            if f" {c}-start(" in stripped or f" {c}-done(" in stripped:
                m = c if "-start(" in stripped else None
                break
        if m is None:
            continue
        # take the shapes on the rhs — for tuples, sum all
        rhs = stripped.split("=", 1)[1] if "=" in stripped else stripped
        # result shape(s) appear at start of rhs before the op name
        op_idx = rhs.find(m)
        result_part = rhs[:op_idx] if op_idx > 0 else rhs
        total = 0
        for dt, dims in _SHAPE_RE.findall(result_part):
            total += _shape_bytes(dt, dims)
        if total == 0:  # fall back: any shape on the line
            for dt, dims in _SHAPE_RE.findall(stripped):
                total += _shape_bytes(dt, dims)
                break
        out[m] += total
        counts[m] += 1
    out_all = dict(out)
    out_all["counts"] = counts
    return out_all


# ---------------------------------------------------------------------------
# model FLOPs (analytic)
# ---------------------------------------------------------------------------


def model_flops(cfg: ArchConfig, shape: InputShape, n_params: int,
                n_active: Optional[int] = None) -> float:
    """6·N·D for training, 2·N·D for inference (N = active params)."""
    n = n_active if (n_active and cfg.n_experts) else n_params
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch  # one token per sequence
    return 2.0 * n * tokens


def active_params(cfg: ArchConfig, n_params: int) -> int:
    """Rough active-parameter count for MoE (top-k of E experts)."""
    if not cfg.n_experts:
        return n_params
    F = cfg.moe_d_ff or cfg.d_ff
    expert_params = cfg.n_layers * cfg.n_experts * 3 * cfg.d_model * F
    active_expert = expert_params * cfg.experts_per_token / cfg.n_experts
    return int(n_params - expert_params + active_expert)


# ---------------------------------------------------------------------------
# dry-run core
# ---------------------------------------------------------------------------


def _lower_combo(cfg: ArchConfig, shape: InputShape, mesh) -> Any:
    """Build the jitted step for (cfg, shape) and AOT-lower it."""
    model = build_model(cfg)
    if shape.kind == "train":
        opt = adamw(1e-4)
        state_shapes = train_state_shapes(model, opt)
        batch_shapes = model.input_spec(shape)
        # optimizer state mirrors the params' sharding (ZeRO for free)
        state_specs = {
            "params": shd.tree_param_specs(state_shapes["params"], mesh,
                                           n_kv_heads=cfg.n_kv_heads),
            "opt": {k: shd.tree_param_specs(v, mesh,
                                            n_kv_heads=cfg.n_kv_heads)
                    for k, v in state_shapes["opt"].items()},
            "step": jax.sharding.PartitionSpec(),
        }
        batch_specs = shd.batch_spec(batch_shapes, mesh)
        jitted = jax.jit(
            make_train_step(model, opt),
            in_shardings=(shd.to_named(state_specs, mesh),
                          shd.to_named(batch_specs, mesh)),
            donate_argnums=(0,),
        )
        return jitted.lower(state_shapes, batch_shapes), {}
    params_shapes = jax.eval_shape(model.init, jax.random.key(0))
    param_specs = shd.tree_param_specs(params_shapes, mesh,
                                       n_kv_heads=cfg.n_kv_heads)
    batch_shapes = model.input_spec(shape)
    batch_specs = shd.batch_spec(batch_shapes, mesh)
    if shape.kind == "prefill":
        jitted = jax.jit(
            make_prefill_step(model),
            in_shardings=(shd.to_named(param_specs, mesh),
                          shd.to_named(batch_specs, mesh)),
        )
        return jitted.lower(params_shapes, batch_shapes), {}
    # decode
    cache_len = model.cache_len_for(shape.seq_len)
    window = model.decode_window_for(shape.seq_len)
    cache_shapes = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, cache_len))
    cache_specs = shd.cache_spec(cache_shapes, mesh)
    jitted = jax.jit(
        make_decode_step(model, window=window),
        in_shardings=(shd.to_named(param_specs, mesh),
                      shd.to_named(cache_specs, mesh),
                      shd.to_named(batch_specs, mesh)),
        donate_argnums=(1,),
    )
    lowered = jitted.lower(params_shapes, cache_shapes, batch_shapes)
    return lowered, {"cache_len": cache_len, "window": window}


def _compile_costs(lowered) -> Dict[str, Any]:
    """Compile and pull flops/bytes/collectives out of the artifact."""
    t0 = time.time()
    compiled = lowered.compile()
    out: Dict[str, Any] = {"compile_s": round(time.time() - t0, 2)}
    mem = compiled.memory_analysis()
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        out[attr] = getattr(mem, attr, None)
    cost = compiled.cost_analysis() or {}
    out["flops"] = cost.get("flops", 0.0)
    out["bytes_accessed"] = cost.get("bytes accessed", 0.0)
    hlo = compiled.as_text()
    out["collectives"] = collective_bytes(hlo)
    out["hlo_len"] = len(hlo)
    return out


def _calib_cfgs(cfg: ArchConfig):
    """1-unit and 2-unit unrolled variants + the unit count for extrapolation."""
    base = dict(unroll_layers=True, unroll_attn=True, attn_chunk=4096,
                loss_chunk=1 << 30)
    if cfg.family == "hybrid":
        e = cfg.attn_every
        units = cfg.n_layers // e
        return (cfg.with_(n_layers=e, **base),
                cfg.with_(n_layers=2 * e, **base), units)
    if cfg.family == "audio":
        return (cfg.with_(n_layers=1, encoder_layers=1, **base),
                cfg.with_(n_layers=2, encoder_layers=2, **base),
                cfg.n_layers)
    return (cfg.with_(n_layers=1, **base),
            cfg.with_(n_layers=2, **base), cfg.n_layers)


def _extrapolate(c1: Dict[str, Any], c2: Dict[str, Any], units: int) -> Dict[str, Any]:
    """True-depth cost estimate: C(L) = C(1) + (L-1) * (C(2) - C(1))."""
    out: Dict[str, Any] = {}
    for k in ("flops", "bytes_accessed"):
        per_unit = (c2[k] or 0) - (c1[k] or 0)
        out[k] = (c1[k] or 0) + (units - 1) * per_unit
    coll: Dict[str, Any] = {}
    for name in _COLLECTIVES:
        per_unit = c2["collectives"][name] - c1["collectives"][name]
        coll[name] = c1["collectives"][name] + (units - 1) * per_unit
    coll["counts"] = {
        name: c1["collectives"]["counts"][name]
        + (units - 1) * (c2["collectives"]["counts"][name]
                         - c1["collectives"]["counts"][name])
        for name in _COLLECTIVES}
    out["collectives"] = coll
    return out


def dryrun_one(arch: str, shape_name: str, *, multi_pod: bool = False,
               calibrate: bool = True) -> Dict[str, Any]:
    cfg = get_arch(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = build_model(cfg)
    record: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "multi_pod": multi_pod,
    }

    from repro.util import use_mesh

    # jax.set_mesh on new jax, `with mesh:` on 0.4.x
    with use_mesh(mesh):
        try:
            # ---- the deliverable: full production config lowers + compiles
            t0 = time.time()
            lowered, extra = _lower_combo(cfg, shape, mesh)
            record.update(extra)
            record["lower_s"] = round(time.time() - t0, 2)
            main = _compile_costs(lowered)
            record.update(main)
            record["status"] = "ok"

            # ---- analytic reference
            n_params = sum(x.size for x in jax.tree.leaves(
                jax.eval_shape(model.init, jax.random.key(0))))
            n_act = active_params(cfg, n_params)
            record["n_params"] = int(n_params)
            record["n_active_params"] = int(n_act)
            record["model_flops"] = model_flops(cfg, shape, n_params, n_act)

            # ---- cost calibration: scans hide per-layer cost from XLA's
            # analysis, so extrapolate true depth from unrolled 1/2-unit runs.
            flops = main["flops"] or 0.0
            byts = main["bytes_accessed"] or 0.0
            coll = main["collectives"]
            if calibrate:
                try:
                    cfg1, cfg2, units = _calib_cfgs(cfg)
                    l1, _ = _lower_combo(cfg1, shape, mesh)
                    c1 = _compile_costs(l1)
                    l2, _ = _lower_combo(cfg2, shape, mesh)
                    c2 = _compile_costs(l2)
                    ext = _extrapolate(c1, c2, units)
                    record["calibrated"] = True
                    record["calib_units"] = units
                    record["calib_compile_s"] = c1["compile_s"] + c2["compile_s"]
                    flops = ext["flops"]
                    byts = ext["bytes_accessed"]
                    coll = ext["collectives"]
                    record["flops_extrap"] = flops
                    record["bytes_extrap"] = byts
                    record["collectives_extrap"] = coll
                except Exception as e:  # noqa: BLE001
                    record["calibrated"] = False
                    record["calib_error"] = f"{type(e).__name__}: {e}"[:300]

            coll_total = sum(v for k, v in coll.items() if k != "counts")
            record["collective_bytes_total"] = coll_total
            # cost_analysis FLOPs/bytes are per-device-program (SPMD), i.e.
            # one chip's slice — roofline terms are per chip directly.
            record["t_compute_s"] = flops / PEAK_FLOPS_BF16
            record["t_memory_s"] = byts / HBM_BW
            record["t_collective_s"] = coll_total / ICI_BW
            terms = {"compute": record["t_compute_s"],
                     "memory": record["t_memory_s"],
                     "collective": record["t_collective_s"]}
            record["bottleneck"] = max(terms, key=terms.get)
            return record
        except Exception as e:  # noqa: BLE001 — we want the failure in the table
            record["status"] = "error"
            record["error"] = f"{type(e).__name__}: {e}"[:500]
            return record


LONG_SKIP: Dict[str, str] = {}  # all archs lower for long_500k (window cache)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--no-calib", action="store_true",
                    help="skip the unrolled cost-calibration lowerings")
    ap.add_argument("--out", default="benchmarks/dryrun_results.json")
    args = ap.parse_args()

    from repro.configs.all_archs import ASSIGNED_ARCHS

    combos = []
    if args.all:
        for a in ASSIGNED_ARCHS:
            for s in INPUT_SHAPES:
                combos.append((a, s))
    else:
        combos.append((args.arch, args.shape))

    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"], r.get("multi_pod", False))
            for r in results if r.get("status") == "ok"}

    for arch, shape in combos:
        key = (arch, shape, args.multipod)
        if key in done:
            print(f"[skip] {arch} x {shape} (cached)")
            continue
        print(f"[dryrun] {arch} x {shape} multi_pod={args.multipod} ...",
              flush=True)
        rec = dryrun_one(arch, shape, multi_pod=args.multipod,
                         calibrate=not args.no_calib)
        print(f"  -> {rec['status']}"
              + (f" compile={rec.get('compile_s')}s"
                 f" flops={rec.get('flops'):.3g}"
                 f" bottleneck={rec.get('bottleneck')}"
                 if rec["status"] == "ok" else f" {rec.get('error','')[:200]}"),
              flush=True)
        results = [r for r in results
                   if not (r["arch"] == arch and r["shape"] == shape
                           and r.get("multi_pod", False) == args.multipod)]
        results.append(rec)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
