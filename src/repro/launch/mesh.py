"""Production mesh definitions (TPU v5e pods).

Functions, not module-level constants: importing this module never touches
jax device state. The dry-run entrypoint sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import so these shapes are constructible on the CPU container.
"""
from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """Version-compat ``jax.make_mesh`` (jax < 0.5 has no AxisType; plain
    make_mesh gives the same Auto axes there)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for CPU tests/examples (axes exist, size 1)."""
    return make_mesh((1, 1), ("data", "model"))


def make_data_mesh(n_shards: int):
    """1-D mesh over the ``data`` axis for the sharded data planes
    (DESIGN.md §15): the OTA fold's symbol axis and the retrieval
    arena's row axis both place over it. ``n_shards`` must not exceed
    the visible device count — on the CPU container that means setting
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` *before* the
    first jax import (the multidevice test lane's subprocess helper,
    ``tests/_multidevice.py``, does exactly this)."""
    n = int(n_shards)
    if n < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    avail = len(jax.devices())
    if n > avail:
        raise ValueError(
            f"mesh of {n} data shards needs {n} devices but only {avail} "
            "visible; set XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{n} before jax is imported (or lower the shard count)")
    return make_mesh((n,), ("data",))


# v5e hardware constants for the roofline model (per chip)
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link
