"""Batched decode/serving driver.

CPU usage (reduced config, real tokens):
  PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b \
      --reduced --batch 4 --prompt-len 32 --gen 32

Runs prefill over a batch of synthetic prompts, then step-decodes with the
KV cache (ring-buffer window when --window is below the total length).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_arch
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models.registry import build_model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--window", type=int, default=0,
                    help="sliding-window cache (0 = full)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(args.seed))

    B, P = args.batch, args.prompt_len
    rng = np.random.RandomState(args.seed)
    prompts = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, P)), jnp.int32)
    batch = {"tokens": prompts}
    if cfg.family == "vlm":
        batch["patches"] = jnp.zeros((B, 8, cfg.frontend_dim), jnp.float32)
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.randn(B, cfg.encoder_seq, cfg.frontend_dim), jnp.float32)

    total = P + args.gen
    window = args.window or 0

    t0 = time.time()
    if model.prefill is not None:
        logits, cache = jax.jit(make_prefill_step(model))(params, batch)
        # grow the cache to hold generated tokens (attention caches only)
        if cfg.family not in ("ssm",):
            cache = model.grow_cache(cache, window or total)
    else:
        cache = model.init_cache(B, total if not window else window)
        logits = jnp.zeros((B, cfg.vocab_size))
    t_prefill = time.time() - t0

    decode = jax.jit(make_decode_step(model, window=window))
    tok = jnp.argmax(logits, -1).astype(jnp.int32).reshape(B, 1)
    out_tokens = [tok]
    t0 = time.time()
    for s in range(args.gen - 1):
        step_batch = {"tokens": tok,
                      "pos": jnp.full((B,), P + s, jnp.int32)}
        logits, cache = decode(params, cache, step_batch)
        tok = jnp.argmax(logits, -1).astype(jnp.int32).reshape(B, 1)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen = jnp.concatenate(out_tokens, axis=1)
    print(f"arch={cfg.name} batch={B} prompt={P} gen={args.gen}")
    print(f"prefill: {t_prefill*1000:.1f} ms   "
          f"decode: {t_decode/max(args.gen-1,1)*1000:.2f} ms/token")
    print("sample token ids:", np.asarray(gen[0])[:16].tolist())


if __name__ == "__main__":
    main()
