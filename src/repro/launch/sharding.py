"""Parameter / input / cache PartitionSpec rules for the production mesh.

Megatron-style tensor parallelism over ``model`` (attention heads, FFN
width, MoE experts, SSM channels) composed with FSDP over ``data`` (and
``pod``) on the complementary dimension. Rules are name-based over the
pytree path and guarded by divisibility — a dim that doesn't divide the
axis size stays unsharded rather than failing at compile.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

Pytree = Any

# leaf names whose *last* dim is the parallel (output) dim
COL_PARALLEL = {"wq", "wk", "wv", "w_gate", "w_up", "in_proj", "x_proj",
                "dt_proj", "router", "frame_proj", "vis_proj", "w_x", "w_h",
                "conv1_w", "conv2_w", "out_w"}
# leaf names whose *first non-stack* dim is the parallel (input) dim
ROW_PARALLEL = {"wo", "w_down", "out_proj"}


def _axis_size(mesh: Mesh, name) -> int:
    if isinstance(name, tuple):
        out = 1
        for n in name:
            out *= _axis_size(mesh, n)
        return out
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def _fits(dim: int, mesh: Mesh, axis) -> bool:
    return axis is not None and dim % _axis_size(mesh, axis) == 0


def _dp_axis(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def param_spec(path: Tuple[str, ...], shape: Tuple[int, ...], mesh: Mesh,
               n_kv_heads: int = 0) -> P:
    """PartitionSpec for one parameter leaf addressed by its dict path."""
    name = path[-1]
    dp = _dp_axis(mesh)
    mp = "model"
    nd = len(shape)

    # GQA: wk/wv output dims are (kv_heads * head_dim). If the kv-head
    # count doesn't divide the TP axis, sharding the flat dim would split
    # head_dim — every attention contraction then partial-sums across the
    # model axis (measured: ~1.7 TB/step of all-reduce on deepseek-67b,
    # §Perf iter 3). Replicate instead: these projections are tiny.
    if name in ("wk", "wv", "bk", "bv") and n_kv_heads:
        if n_kv_heads % _axis_size(mesh, mp) != 0:
            lead = [None] * (nd - 2)
            if nd >= 2:
                return P(*lead, dp if _fits(shape[-2], mesh, dp) else None,
                         None)
            return P(*([None] * nd))

    def guarded(*entries):
        out = []
        for dim, ax in zip(shape, entries):
            out.append(
                ax if _fits(dim, mesh, ax if isinstance(ax, tuple) else ax)
                else None)
        return P(*out)

    if name == "embed":
        return guarded(mp, dp)
    if name == "lm_head":
        # vocab-parallel ONLY: sharding the contraction (d) dim over data
        # would make every logits matmul all-reduce a (B,S,V) tensor across
        # the data axis (measured: 104 GB/step on stablelm — §Perf iter 1).
        return guarded(None, mp)
    # MoE expert-stacked weights: (L, E, a, b) or (E, a, b)
    if name in ("w_gate", "w_up", "w_down") and nd >= 3 and "moe" in path:
        lead = [None] * (nd - 3)
        e, a, b = shape[-3:]
        e_ax = mp if _fits(e, mesh, mp) else None
        if name == "w_down":
            return P(*lead, e_ax, None, dp if _fits(b, mesh, dp) else None)
        return P(*lead, e_ax, dp if _fits(a, mesh, dp) else None, None)
    if name in COL_PARALLEL and nd >= 2:
        lead = [None] * (nd - 2)
        a, b = shape[-2:]
        return P(*lead,
                 dp if _fits(a, mesh, dp) else None,
                 mp if _fits(b, mesh, mp) else None)
    if name in ROW_PARALLEL and nd >= 2:
        lead = [None] * (nd - 2)
        a, b = shape[-2:]
        return P(*lead,
                 mp if _fits(a, mesh, mp) else None,
                 dp if _fits(b, mesh, dp) else None)
    if name == "conv_w":  # (L, K, C): shard channels
        return P(*([None] * (nd - 1)),
                 mp if _fits(shape[-1], mesh, mp) else None)
    if name in ("A_log", "D", "dt_bias", "conv_b") and nd >= 1:
        # per-channel SSM params: shard the channel dim (first after stack)
        entries = [None] * nd
        # channel dim is the first non-stack dim for A_log (L, d, N) -> d
        ch_idx = 1 if nd >= 2 else 0
        if _fits(shape[ch_idx], mesh, mp):
            entries[ch_idx] = mp
        return P(*entries)
    # norms, biases, scalars: replicated
    return P(*([None] * nd))


def tree_param_specs(shapes: Pytree, mesh: Mesh,
                     n_kv_heads: int = 0) -> Pytree:
    """Map a pytree of ShapeDtypeStructs to a pytree of PartitionSpecs."""

    def walk(path, node):
        if isinstance(node, dict):
            return {k: walk(path + (k,), v) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            t = type(node)
            return t(walk(path + (str(i),), v) for i, v in enumerate(node))
        return param_spec(path, node.shape, mesh, n_kv_heads=n_kv_heads)

    return walk((), shapes)


def batch_spec(shapes: Dict[str, jax.ShapeDtypeStruct], mesh: Mesh) -> Dict[str, P]:
    """Inputs: shard the batch (first) dim over (pod, data) when divisible."""
    dp = _dp_axis(mesh)
    out = {}
    for k, v in shapes.items():
        if v.ndim >= 1 and _fits(v.shape[0], mesh, dp):
            out[k] = P(dp, *([None] * (v.ndim - 1)))
        else:
            out[k] = P(*([None] * v.ndim))
    return out


def cache_spec(shapes: Pytree, mesh: Mesh) -> Pytree:
    """Decode caches: (L, B, ...) — batch over data when divisible; for
    attention caches also try kv-heads over model; SSM channel dims over
    model."""
    dp = _dp_axis(mesh)
    mp = "model"

    def walk(path, node):
        if isinstance(node, dict):
            return {k: walk(path + (k,), v) for k, v in node.items()}
        name = path[-1]
        s = node.shape
        entries = [None] * len(s)
        # find batch dim: caches are stacked (L, B, ...) or (L, seg, B, ...)
        for i, d in enumerate(s[:3]):
            if _fits(d, mesh, dp) and i >= 1:
                entries[i] = dp
                break
        if name in ("k", "v") and len(s) >= 2:
            if _fits(s[-2], mesh, mp):
                entries[-2] = mp
        if name in ("h", "ssm_h", "conv", "ssm_conv") and len(s) >= 2:
            # channel-ish dim: h (L,B,di,N) -> di; conv (L,B,K-1,di) -> di
            idx = -2 if name in ("h", "ssm_h") else -1
            if _fits(s[idx], mesh, mp):
                entries[idx] = mp
        return P(*entries)

    return walk((), shapes)


def to_named(spec_tree: Pytree, mesh: Mesh) -> Pytree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
