"""Generic training driver for the architecture pool.

Single-host CPU usage (reduced configs, real steps):
  PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b \
      --reduced --steps 50 --batch 8 --seq 128

Production usage (TPU pod; this container can only dry-run it):
  python -m repro.launch.train --arch qwen3-8b --mesh 16x16 ...

The FL voice-assistant experiment (the paper's §IV) has its own driver:
``examples/train_fl_voice.py``.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import CheckpointManager
from repro.configs.base import get_arch
from repro.data.lm import token_batches
from repro.launch.steps import init_train_state, make_train_step
from repro.models.registry import build_model
from repro.optim import adamw, linear_warmup_cosine
from repro.util import count_params


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced (smoke) variant on CPU")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    opt = adamw(linear_warmup_cosine(args.lr, args.warmup, args.steps))

    state = init_train_state(model, opt, jax.random.key(args.seed))
    print(f"arch={cfg.name} params={count_params(state['params']):,}")

    step_fn = jax.jit(make_train_step(model, opt), donate_argnums=(0,))
    data = token_batches(cfg.vocab_size, args.batch, args.seq, seed=args.seed)

    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if mgr is not None:
        restored, meta = mgr.restore_latest()
        if restored is not None:
            state = restored
            print(f"restored step {meta['step']}")

    t0 = time.time()
    start = int(state["step"])
    for i in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        if cfg.family == "vlm":
            batch["patches"] = jnp.zeros(
                (args.batch, 8, cfg.frontend_dim), jnp.float32)
        if cfg.family == "audio":
            batch["frames"] = jnp.asarray(np.random.RandomState(i).randn(
                args.batch, cfg.encoder_seq, cfg.frontend_dim), jnp.float32)
        state, metrics = step_fn(state, batch)
        if (i + 1) % args.log_every == 0:
            dt = (time.time() - t0) / max(i + 1 - start, 1)
            print(f"step {i+1:5d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"({dt*1000:.0f} ms/step)")
        if mgr is not None and (i + 1) % args.ckpt_every == 0:
            mgr.save(i + 1, state)
    print(f"done: {args.steps} steps in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
