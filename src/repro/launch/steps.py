"""jit-able train / prefill / decode steps over the model zoo.

``make_train_step`` builds the canonical FSDP+TP training step; the FL
simulator reuses the same step per client at its planned precision via
``quantized_train_step`` (weights fake-quantized in the forward pass —
the client "operates at" its precision level).
"""
from __future__ import annotations

from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.models.registry import Model
from repro.optim import Optimizer, clip_by_global_norm

Pytree = Any


def init_train_state(model: Model, opt: Optimizer, key) -> Dict[str, Any]:
    params = model.init(key)
    return {"params": params, "opt": opt.init(params),
            "step": jnp.zeros((), jnp.int32)}


def train_state_shapes(model: Model, opt: Optimizer) -> Dict[str, Any]:
    """abstract train state (no allocation) for AOT lowering."""
    params = jax.eval_shape(model.init, jax.random.key(0))
    opt_state = jax.eval_shape(opt.init, params)
    return {"params": params, "opt": opt_state,
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def make_train_step(model: Model, opt: Optimizer, *,
                    clip_norm: float = 1.0) -> Callable:
    def train_step(state: Dict[str, Any], batch: Dict[str, jnp.ndarray]):
        def loss_fn(params):
            return model.loss(params, batch)

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state["params"])
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        updates, opt_state = opt.update(grads, state["opt"], state["params"],
                                        state["step"])
        params = jax.tree.map(
            lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
            state["params"], updates)
        new_state = {"params": params, "opt": opt_state,
                     "step": state["step"] + 1}
        metrics = dict(metrics, loss=loss, grad_norm=gnorm)
        return new_state, metrics

    return train_step


def make_quantized_train_step(model: Model, opt: Optimizer, bits: int, *,
                              clip_norm: float = 1.0,
                              fedprox_mu: float = 0.0) -> Callable:
    """Client-side local step at precision ``bits``: the forward runs on
    fake-quantized weights (straight-through gradients). With
    ``fedprox_mu`` > 0 a proximal pull toward the round's global weights
    (carried in ``state["anchor"]``) is added to the gradients (FedProx —
    stabilises heterogeneous local training)."""

    def train_step(state, batch):
        def loss_fn(params):
            qparams = jax.tree.map(
                lambda p: quant.ste_fake_quant(p, bits)
                if p.ndim >= 2 else p, params)
            return model.loss(qparams, batch)

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state["params"])
        if fedprox_mu > 0.0 and "anchor" in state:
            grads = jax.tree.map(
                lambda g, p, a: g + fedprox_mu * (
                    p.astype(jnp.float32) - a.astype(jnp.float32)
                ).astype(g.dtype),
                grads, state["params"], state["anchor"])
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        updates, opt_state = opt.update(grads, state["opt"], state["params"],
                                        state["step"])
        params = jax.tree.map(
            lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
            state["params"], updates)
        new_state = {"params": params, "opt": opt_state,
                     "step": state["step"] + 1}
        if "anchor" in state:  # FedProx anchor rides along unchanged
            new_state["anchor"] = state["anchor"]
        return new_state, dict(metrics, loss=loss, grad_norm=gnorm)

    return train_step


def make_prefill_step(model: Model) -> Callable:
    def prefill_step(params, batch):
        return model.prefill(params, batch)

    return prefill_step


def make_decode_step(model: Model, *, window: int = 0) -> Callable:
    def decode_step(params, cache, batch):
        return model.decode(params, cache, batch, window=window)

    return decode_step
