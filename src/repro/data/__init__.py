from repro.data.voice import (  # noqa: F401
    CHAR_TO_ID, FEAT_DIM, FRAMES_PER_CHAR, VOCAB, VOCAB_SIZE, ClientShard,
    Utterance, batchify, encode_text, make_client_shard, make_eval_set,
    sample_command, synth_frames,
)
from repro.data.lm import MarkovTokens, token_batches  # noqa: F401
