"""Synthetic LM token pipeline for the architecture-pool training shapes.

A small-order Markov chain over the vocabulary generates streams with
learnable structure (so example training runs show a real loss descent,
not just unigram collapse), plus an infinite batch iterator with
host-side prefetch semantics (numpy generation, device put by the caller).
"""
from __future__ import annotations

from typing import Dict, Iterator

import numpy as np


class MarkovTokens:
    """Order-1 Markov token source with a sparse, seeded transition graph."""

    def __init__(self, vocab_size: int, branching: int = 8, seed: int = 0):
        rng = np.random.RandomState(seed)
        self.vocab = vocab_size
        self.next_ids = rng.randint(0, vocab_size,
                                    size=(vocab_size, branching)).astype(np.int32)
        probs = rng.dirichlet(np.ones(branching) * 0.6, size=vocab_size)
        self.probs = probs.astype(np.float64)

    def sample(self, rng: np.random.RandomState, batch: int,
               seq_len: int) -> np.ndarray:
        out = np.empty((batch, seq_len), np.int32)
        cur = rng.randint(0, self.vocab, size=batch)
        for t in range(seq_len):
            out[:, t] = cur
            choice = np.array([
                rng.choice(self.next_ids[c], p=self.probs[c]) for c in cur
            ])
            cur = choice
        return out


def token_batches(vocab_size: int, batch: int, seq_len: int, *,
                  seed: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    src = MarkovTokens(vocab_size, seed=seed)
    rng = np.random.RandomState(seed + 1)
    while True:
        yield {"tokens": src.sample(rng, batch, seq_len)}
