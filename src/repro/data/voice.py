"""Synthetic federated voice-command corpus (stands in for Common Voice).

The paper filters Common Voice into four smart-assistant categories with
the Table II mixture (32.7 / 16.0 / 31.9 / 19.4 %). Offline we synthesise:

- **text**: per-category command templates with slot fillers (char-level
  tokens, vocab 64, id 0 = CTC blank / pad);
- **"audio" frames**: each character emits ``FRAMES_PER_CHAR`` frames of a
  character-specific random projection (fixed by a global seed — the
  "acoustic model" of the synthetic world) plus AWGN whose level comes
  from the client's operational context (bedroom vs kitchen etc., per
  Table I). A DeepSpeech2-style model genuinely has to learn the
  char→frame correspondence through CTC, and noisy-context clients
  genuinely have harder data — which is what makes contribution/precision
  planning matter.
- **client shards**: category mixtures from each simulated user's truth,
  shard size from their data-quantity factor (interaction frequency/time).
"""
from __future__ import annotations

import dataclasses
import random
from typing import Dict, List, Sequence

import numpy as np

from repro.core.profiling.users import CATEGORIES, UserTruth

# char vocab: 0=blank/pad, 1=space, 2-27=a-z, 28='
VOCAB = ["<blank>", " "] + [chr(c) for c in range(ord("a"), ord("z") + 1)] + ["'"]
VOCAB_SIZE = 64  # padded to a round size (ids above 28 unused)
CHAR_TO_ID = {c: i for i, c in enumerate(VOCAB)}
# conv frontend downsamples 4x; 8 frames/char leaves T' = 2L after the
# convs, giving CTC the slack it needs for blanks between repeated chars.
FRAMES_PER_CHAR = 8
FEAT_DIM = 80

TEMPLATES: Dict[str, List[str]] = {
    "entertainment": [
        "play some {g} music", "put on my {g} playlist", "play the next song",
        "turn up the volume", "play {g} radio", "shuffle my {g} songs",
    ],
    "smart_home": [
        "turn off the {r} lights", "set the thermostat to twenty",
        "lock the front door", "dim the lights in the {r}",
        "turn on the {r} plug", "start the robot vacuum",
    ],
    "general_query": [
        "what is the weather today", "how far is the moon",
        "what time is it in tokyo", "who won the game last night",
        "how many ounces in a pound", "what is the news this morning",
    ],
    "personal_request": [
        "remind me to call mom", "add milk to my shopping list",
        "set an alarm for seven", "what is on my calendar today",
        "cancel my three o'clock meeting", "note that i parked on level two",
    ],
}
SLOTS = {
    "g": ["jazz", "rock", "pop", "classical", "folk", "blues"],
    "r": ["kitchen", "bedroom", "living room", "office", "hallway"],
}


def encode_text(text: str) -> np.ndarray:
    return np.array([CHAR_TO_ID[c] for c in text if c in CHAR_TO_ID],
                    np.int32)


def sample_command(rng: random.Random, category: str) -> str:
    t = rng.choice(TEMPLATES[category])
    for slot, fillers in SLOTS.items():
        t = t.replace("{" + slot + "}", rng.choice(fillers))
    return t


# fixed "acoustics": char id -> base feature vector
def _char_bank(seed: int = 1234) -> np.ndarray:
    rng = np.random.RandomState(seed)
    bank = rng.randn(VOCAB_SIZE, FEAT_DIM).astype(np.float32)
    return bank / np.linalg.norm(bank, axis=1, keepdims=True) * 3.0


CHAR_BANK = _char_bank()


def synth_frames(label_ids: np.ndarray, noise_level: float,
                 rng: np.random.RandomState) -> np.ndarray:
    """(len,) char ids -> (len*FRAMES_PER_CHAR, FEAT_DIM) noisy frames."""
    base = CHAR_BANK[label_ids]  # (L, F)
    frames = np.repeat(base, FRAMES_PER_CHAR, axis=0)
    # mild temporal smearing (coarticulation)
    if len(frames) > 2:
        frames[1:] = 0.85 * frames[1:] + 0.15 * frames[:-1]
    noise = rng.randn(*frames.shape).astype(np.float32)
    return frames + noise * (0.25 + 1.4 * noise_level)


@dataclasses.dataclass
class Utterance:
    text: str
    category: str
    label_ids: np.ndarray
    frames: np.ndarray


@dataclasses.dataclass
class ClientShard:
    user_id: int
    utterances: List[Utterance]

    def category_counts(self) -> Dict[str, int]:
        out = {c: 0 for c in CATEGORIES}
        for u in self.utterances:
            out[u.category] += 1
        return out


def make_client_shard(user: UserTruth, *, base_size: int = 24,
                      seed: int = 0) -> ClientShard:
    rng = random.Random(seed * 100003 + user.user_id)
    nrng = np.random.RandomState(seed * 7919 + user.user_id)
    n = max(4, int(base_size * (0.5 + user.data_quantity)))
    cats = list(user.category_mix.keys())
    probs = list(user.category_mix.values())
    utts = []
    for _ in range(n):
        cat = rng.choices(cats, probs)[0]
        text = sample_command(rng, cat)
        ids = encode_text(text)
        utts.append(Utterance(
            text=text, category=cat, label_ids=ids,
            frames=synth_frames(ids, user.noise_level, nrng)))
    return ClientShard(user.user_id, utts)


def make_eval_set(n: int = 120, *, noise_level: float = 0.3,
                  seed: int = 999) -> List[Utterance]:
    """Server-side balanced eval set (per-category accuracy for Fig. 4)."""
    rng = random.Random(seed)
    nrng = np.random.RandomState(seed)
    out = []
    per_cat = n // len(CATEGORIES)
    for cat in CATEGORIES:
        for _ in range(per_cat):
            text = sample_command(rng, cat)
            ids = encode_text(text)
            out.append(Utterance(text=text, category=cat, label_ids=ids,
                                 frames=synth_frames(ids, noise_level, nrng)))
    return out


def batchify(utts: Sequence[Utterance], max_frames: int = 0,
             max_labels: int = 0) -> Dict[str, np.ndarray]:
    """Pad a list of utterances into fixed arrays for the DS2 model."""
    B = len(utts)
    TF = max_frames or max(len(u.frames) for u in utts)
    TL = max_labels or max(len(u.label_ids) for u in utts)
    frames = np.zeros((B, TF, FEAT_DIM), np.float32)
    labels = np.zeros((B, TL), np.int32)
    frame_len = np.zeros((B,), np.int32)
    label_len = np.zeros((B,), np.int32)
    for i, u in enumerate(utts):
        f = u.frames[:TF]
        l = u.label_ids[:TL]
        frames[i, : len(f)] = f
        labels[i, : len(l)] = l
        frame_len[i] = len(f)
        label_len[i] = len(l)
    return {"frames": frames, "labels": labels,
            "frame_len": frame_len, "label_len": label_len}
