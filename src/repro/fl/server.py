"""The MP-OTA-FL server: client selection, multi-client quantization
planning (via the paper's RAG planner or the unified baseline), OTA
aggregation, and per-round feedback collection into the RAG databases.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, FLConfig, get_arch
from repro.core import ota, packing
from repro.core.profiling.hardware import make_fleet
from repro.core.profiling.planner import (BasePlanner, RAGPlanner,
                                          UnifiedTierPlanner, plan_round)
from repro.core.profiling.users import (drift_device, drift_user, make_users,
                                        satisfaction_score, true_performance)
from repro.data.voice import (Utterance, batchify, make_client_shard,
                              make_eval_set)
from repro.fl.client import FLClient
from repro.models.deepspeech2 import ds2_greedy_decode
from repro.models.registry import build_model

Pytree = Any


def make_planner(cfg: FLConfig) -> BasePlanner:
    if cfg.planner == "unified":
        return UnifiedTierPlanner()
    if cfg.planner == "rag":
        return RAGPlanner(strategy=cfg.strategy, seed=cfg.seed)
    if cfg.planner == "rag_energy":
        return RAGPlanner(strategy=cfg.strategy, energy_priority=8.0,
                          seed=cfg.seed)
    raise ValueError(f"unknown planner {cfg.planner!r}")


@dataclasses.dataclass
class RoundLog:
    round: int
    bits: Dict[int, int]
    mean_satisfaction: float
    mean_energy: float
    n_participating: int
    train_loss: float


class FLServer:
    """Owns the global model and runs the federated rounds."""

    def __init__(self, fl_cfg: FLConfig, arch: Optional[ArchConfig] = None,
                 *, shard_size: int = 24):
        self.cfg = fl_cfg
        self.arch = arch or get_arch("deepspeech2")
        self.model = build_model(self.arch)
        self.users = make_users(fl_cfg.n_clients, seed=fl_cfg.seed)
        self.fleet = make_fleet(fl_cfg.n_clients, seed=fl_cfg.seed)
        self.clients = [
            FLClient(u, s, make_client_shard(u, base_size=shard_size,
                                             seed=fl_cfg.seed), self.model)
            for u, s in zip(self.users, self.fleet)
        ]
        self.planner = make_planner(fl_cfg)
        self.params = self.model.init(jax.random.key(fl_cfg.seed))
        # one flat layout for the whole federation: clients pack their
        # deltas onto it, the OTA data plane aggregates rows (core/ota.py)
        self.layout = packing.make_layout(self.params)
        self.round_logs: List[RoundLog] = []
        self._rng = np.random.RandomState(fl_cfg.seed + 7)

    # -- client selection (round-robin batches, paper default scheduling)
    def select(self, rnd: int) -> List[int]:
        n = self.cfg.n_clients
        k = self.cfg.clients_per_round
        start = (rnd * k) % n
        return [(start + i) % n for i in range(k)]

    def run_round(self, rnd: int) -> RoundLog:
        ids = self.select(rnd)
        users = [self.users[i] for i in ids]
        specs = [self.fleet[i] for i in ids]

        # ---- context / hardware drift (paper §III-A interview triggers 2/3):
        # users move devices, schedules shift, batteries drain — changed
        # clients get re-profiled by the planner's next interview pass.
        import random as _random

        drift_rng = _random.Random(self.cfg.seed * 7919 + rnd)
        n_context_changes = sum(drift_user(u, drift_rng) for u in users)
        n_hw_changes = sum(drift_device(s, drift_rng) for s in specs)
        self.last_drift = (n_context_changes, n_hw_changes)

        # ---- multi-client quantization planning (profiling pipeline):
        # cohort-batched — one RAG engine query per store for the whole
        # round instead of a per-client scan (DESIGN.md §10)
        decisions = plan_round(self.planner.plan_cohort(users, specs))
        bits = {d.user_id: d.bits for d in decisions}

        # ---- local training at the planned precision (stragglers drop out).
        # The round key is fixed before the client loop so clients can
        # quantize + bit-pack their uplinks at the edge with the round's
        # shared dither stream (ota.derive_sr_seed); the server only ever
        # sees PackedRow wire rows, never the f32 (K, M) matrix.
        round_key = jax.random.key(self.cfg.seed * 131 + rnd)
        sr_seed = ota.derive_sr_seed(round_key)
        deltas, weights, losses, active_ids = [], [], [], []
        drop_rng = np.random.RandomState(self.cfg.seed * 1237 + rnd)
        for d, i in zip(decisions, ids):
            if self.cfg.dropout_prob and \
                    drop_rng.rand() < self.cfg.dropout_prob:
                continue  # straggler: never reports this round
            delta, m = self.clients[i].local_update(
                self.params, d.bits,
                local_steps=self.cfg.local_steps,
                local_batch=self.cfg.local_batch,
                lr=self.cfg.lr, seed=self.cfg.seed * 97 + rnd,
                fedprox_mu=self.cfg.fedprox_mu, layout=self.layout,
                sr_seed=sr_seed, uplink_row=len(deltas),
                quant_block=self.cfg.quant_block)
            deltas.append(delta)
            # FedAvg weight = samples x estimated contribution C_q (the
            # strategy's lever: class-equal upweights minority-rich
            # clients' updates, majority-centric the reverse; plain
            # fedavg has C_q == quantity x precision-quality only).
            contrib = 1.0
            if d.levels:
                sel = next((l for l in d.levels if l.bits == d.bits), None)
                if sel is not None:
                    contrib = sel.contribution
            weights.append(m["n_samples"] * contrib)
            losses.append(m["loss_last"])
            active_ids.append(i)
        if not deltas:  # everyone dropped: skip the aggregation
            log = RoundLog(rnd, bits, 0.0, 0.0, 0, float("nan"))
            self.round_logs.append(log)
            return log

        # ---- mixed-precision OTA aggregation: the clients' quantized,
        # bit-packed wire rows go straight into the fused dequant +
        # superpose data plane (grouped per storage class, DESIGN.md §5)
        agg, info = ota.ota_aggregate_packed(
            round_key, deltas,
            [bits[self.users[i].user_id] for i in active_ids],
            weights, self.layout, ota.OTAConfig(snr_db=self.cfg.snr_db))
        self.last_uplink_bytes = info["uplink_bytes"]
        # server momentum (FedAvgM) on the aggregated update
        if self.cfg.server_momentum > 0.0:
            if not hasattr(self, "_velocity"):
                self._velocity = jax.tree.map(
                    lambda u: jnp.zeros_like(u, jnp.float32), agg)
            self._velocity = jax.tree.map(
                lambda v, u: self.cfg.server_momentum * v + u,
                self._velocity, agg)
            agg = self._velocity
        self.params = jax.tree.map(
            lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
            self.params, agg)

        # ---- feedback: realised satisfaction -> RAG databases
        sats, energies = [], []
        for d, u, s in zip(decisions, users, specs):
            sat = satisfaction_score(u, s, d.bits)
            perf = true_performance(u, s, d.bits)
            self.planner.observe_feedback(u, s, d.bits, sat, perf)
            sats.append(sat)
            energies.append(perf["energy"])

        log = RoundLog(
            round=rnd, bits=bits,
            mean_satisfaction=float(np.mean(sats)),
            mean_energy=float(np.mean(energies)),
            n_participating=info["n_participating"],
            train_loss=float(np.mean(losses)),
        )
        self.round_logs.append(log)
        return log

    def run(self, n_rounds: Optional[int] = None, *, verbose: bool = False):
        for r in range(n_rounds or self.cfg.n_rounds):
            log = self.run_round(r)
            if verbose:
                print(f"round {r:3d} loss={log.train_loss:.3f} "
                      f"sat={log.mean_satisfaction:.3f} "
                      f"energy={log.mean_energy:.3f} "
                      f"clients={log.n_participating}")
        return self.round_logs

    # ---- evaluation (word/char accuracy + CTC loss per category, Fig. 4)
    def evaluate(self, eval_set: Optional[List[Utterance]] = None,
                 batch: int = 24, with_loss: bool = False) -> Dict[str, float]:
        eval_set = eval_set or make_eval_set(seed=self.cfg.seed + 999)
        correct: Dict[str, int] = {}
        total: Dict[str, int] = {}
        loss_sum: Dict[str, float] = {}
        loss_n: Dict[str, int] = {}
        from repro.models.deepspeech2 import ctc_loss, ds2_logits
        import jax.numpy as jnp

        for i in range(0, len(eval_set), batch):
            chunk = eval_set[i : i + batch]
            if len(chunk) < batch:  # keep shapes static for the jit cache
                chunk = list(chunk) + [chunk[-1]] * (batch - len(chunk))
            b = batchify(chunk, max_frames=320, max_labels=40)
            ids = ds2_greedy_decode(self.model_params_fn(),
                                    jnp.asarray(b["frames"]), self.arch)
            ids = np.asarray(ids)
            if with_loss:
                # per-utterance CTC loss (the accuracy metric is blind
                # during CTC's early blank-collapse phase; loss is not)
                lp = ds2_logits(self.model_params_fn(),
                                jnp.asarray(b["frames"]), self.arch)
                in_len = jnp.minimum(jnp.asarray(b["frame_len"]) // 4,
                                     lp.shape[1])
                for j, u in enumerate(chunk):
                    lj = float(ctc_loss(
                        lp[j : j + 1], jnp.asarray(b["labels"][j : j + 1]),
                        in_len[j : j + 1],
                        jnp.asarray(b["label_len"][j : j + 1])))
                    loss_sum[u.category] = loss_sum.get(u.category, 0.0) + lj
                    loss_n[u.category] = loss_n.get(u.category, 0) + 1
            for j, u in enumerate(chunk):
                # char accuracy: collapse decoded, compare to reference
                dec = [t for t in ids[j] if t != 0]
                ref = list(u.label_ids)
                n = max(len(ref), 1)
                # simple alignment-free prefix match score
                m = sum(1 for a, b_ in zip(dec, ref) if a == b_)
                correct[u.category] = correct.get(u.category, 0) + m
                total[u.category] = total.get(u.category, 0) + n
        out = {c: correct.get(c, 0) / max(total.get(c, 1), 1) for c in total}
        if with_loss:
            for c in loss_sum:
                out["loss_" + c] = loss_sum[c] / max(loss_n[c], 1)
        return out

    def model_params_fn(self):
        return self.params
