"""The MP-OTA-FL server: client selection, multi-client quantization
planning (via the paper's RAG planner or the unified baseline), OTA
aggregation, and per-round feedback collection into the RAG databases.

Two round loops share the same planning/training/feedback stages
(DESIGN.md §11):

- ``FLServer.run_round`` — the synchronous barrier: select -> all K
  clients train -> one aggregation. Wall-clock per round is set by the
  slowest straggler, and a single dropout stalls the whole cohort.
- ``StreamingFLServer.run_round`` — the event-driven buffered engine:
  every uplink gets a simulated arrival time (``fl/client.LatencyModel``),
  aggregation fires on cohort-fill or deadline (``plan_stream``), rows
  landing inside the grace window fold in late with a staleness
  discount, and everything folds into one persistent
  ``core/ota.OtaAccumulator``. With no deadline and a full fill target
  the engine degenerates to the barrier and is bit-identical to the
  synchronous path (the equivalence oracle).
"""

from __future__ import annotations

import dataclasses
import math
import random
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.base import ArchConfig, FLConfig, get_arch
from repro.core import channel as chanmod
from repro.core import ota, packing, wire
from repro.optim.optimizers import state_nbytes
from repro.core.profiling.hardware import make_fleet
from repro.core.profiling.planner import (
    BasePlanner,
    RAGPlanner,
    UnifiedTierPlanner,
    plan_round,
)
from repro.core.profiling.users import (
    drift_device,
    drift_user,
    make_users,
    satisfaction_score,
    true_performance,
)
from repro.data.voice import Utterance, batchify, make_client_shard, make_eval_set
from repro.fl.client import FLClient, LatencyModel
from repro.models.deepspeech2 import ds2_greedy_decode
from repro.models.registry import build_model

Pytree = Any


def make_planner(cfg: FLConfig) -> BasePlanner:
    if cfg.planner == "unified":
        return UnifiedTierPlanner()
    if cfg.planner == "rag":
        return RAGPlanner(strategy=cfg.strategy, seed=cfg.seed)
    if cfg.planner == "rag_energy":
        return RAGPlanner(strategy=cfg.strategy, energy_priority=8.0, seed=cfg.seed)
    raise ValueError(f"unknown planner {cfg.planner!r}")


def _mix_stream(*parts: int) -> int:
    """Hash-combine stream coordinates into one 32-bit RNG seed.

    Boost-style avalanche mix: every part perturbs the whole state, so
    distinct (seed, rnd, salt) triples land in distinct streams. The
    previous ``seed * salt + rnd`` collapsed at seed = 0 (the FLConfig
    default!): every salt named the SAME stream, so the dropout draw and
    the streaming latency draw were correlated copies of each other —
    the latent seed-reuse hazard tests/test_channel.py now pins closed.
    """
    h = 0
    for p in parts:
        h ^= (int(p) & 0xFFFFFFFF) + 0x9E3779B9 + \
            ((h << 6) & 0xFFFFFFFF) + (h >> 2)
        h &= 0xFFFFFFFF
    return h


def round_rng(seed: int, rnd: int, salt: int = 1237) -> np.random.RandomState:
    """Seeded per-round numpy RNG (dropout draws, latency draws, ...).

    One helper shared by both round loops so a (seed, rnd, salt) triple
    names exactly one stream (``_mix_stream``) — the streaming server's
    extra draws use distinct salts and never perturb the synchronous
    streams, at every seed including 0.
    """
    return np.random.RandomState(_mix_stream(seed, rnd, salt))


def round_drift_rng(seed: int, rnd: int) -> random.Random:
    """Seeded per-round stdlib RNG for the context/hardware drift stage."""
    return random.Random(_mix_stream(seed, rnd, 7919))


@dataclasses.dataclass
class RoundLog:
    """Typed per-round report (the round-loop side of ``ota.AggregateInfo``).

    ``uplink_bytes``/``downlink_bytes`` are the round's two wire legs —
    the cohort's packed uplink rows and the one broadcast row every
    client receives (DESIGN.md §13) — so round-trip accounting reads
    straight off the log. ``publish`` pushes the same values into the
    ``obs.metrics`` registry (DESIGN.md §14) — the log stays the
    per-round record, the registry the cross-round rollup, and the two
    agree bit-for-bit because one feeds the other.
    """

    round: int
    bits: Dict[int, int]
    mean_satisfaction: float
    mean_energy: float
    n_participating: int
    train_loss: float
    uplink_bytes: int = 0
    downlink_bytes: int = 0

    def publish(self, registry=None) -> "RoundLog":
        m = registry or obs.metrics.REGISTRY
        m.inc("fl.rounds")
        m.inc("fl.uplink_bytes", self.uplink_bytes)
        m.inc("fl.downlink_bytes", self.downlink_bytes)
        m.set_gauge("fl.n_participating", self.n_participating)
        if not math.isnan(self.train_loss):
            m.set_gauge("fl.train_loss", self.train_loss)
        m.set_gauge("fl.mean_satisfaction", self.mean_satisfaction)
        m.set_gauge("fl.mean_energy", self.mean_energy)
        return self


class FLServer:
    """Owns the global model and runs the federated rounds."""

    def __init__(
        self,
        fl_cfg: FLConfig,
        arch: Optional[ArchConfig] = None,
        *,
        shard_size: int = 24,
    ):
        self.cfg = fl_cfg
        self.arch = arch or get_arch("deepspeech2")
        self.model = build_model(self.arch)
        self.users = make_users(fl_cfg.n_clients, seed=fl_cfg.seed)
        self.fleet = make_fleet(fl_cfg.n_clients, seed=fl_cfg.seed)
        self.clients = [
            FLClient(
                u,
                s,
                make_client_shard(u, base_size=shard_size, seed=fl_cfg.seed),
                self.model,
            )
            for u, s in zip(self.users, self.fleet)
        ]
        self.planner = make_planner(fl_cfg)
        self.params = self.model.init(jax.random.key(fl_cfg.seed))
        # one flat layout for the whole federation: clients pack their
        # deltas onto it, the OTA data plane aggregates rows (core/ota.py)
        self.layout = packing.make_layout(self.params)
        # server-side flat state (DESIGN.md §13): ``_master`` is the f32
        # optimizer-side params vector, ``_bcast`` the fleet's replica —
        # what the clients reconstructed from the last downlink broadcast
        # (== master under the f32 passthrough; under a quantized
        # downlink, master - bcast is the residual the next broadcast
        # re-sends: implicit error feedback). ``self.params`` is always
        # the unpacked ``_bcast`` — server and clients train/evaluate on
        # the same reconstruction.
        self._master = packing.pack(self.params, self.layout)
        self._bcast = self._master
        self.last_broadcast: Optional[packing.PackedRow] = None
        self.last_downlink_bytes = 0
        # physical OTA channel (DESIGN.md §12): None = legacy ideal path
        if fl_cfg.channel_model == "fading":
            self.channel: Optional[chanmod.ChannelModel] = chanmod.ChannelModel(
                chanmod.ChannelConfig(
                    fade_threshold=fl_cfg.fade_threshold,
                    power_budget=fl_cfg.tx_power_budget,
                    pathloss_spread_db=fl_cfg.pathloss_spread_db,
                )
            )
        elif fl_cfg.channel_model == "ideal":
            self.channel = None
        else:
            raise ValueError(f"unknown channel_model {fl_cfg.channel_model!r}")
        # mesh-sharded OTA data plane (DESIGN.md §15): both round loops
        # aggregate on this mesh when the knob is set; the sharded fold
        # is bit-identical to the single-host one, so the knob never
        # changes a run's trajectory.
        if fl_cfg.mesh_data_shards > 1:
            from repro.launch.mesh import make_data_mesh
            self.mesh = make_data_mesh(fl_cfg.mesh_data_shards)
        else:
            self.mesh = None
        self._chan_hist: Dict[int, List[int]] = {}  # id -> [n_trunc, n_seen]
        self.round_logs: List[RoundLog] = []
        self._rng = np.random.RandomState(fl_cfg.seed + 7)

    def _log_round(self, log: RoundLog) -> RoundLog:
        """Record the round log and publish it into ``obs.metrics``."""
        self.round_logs.append(log.publish())
        return log

    # -- client selection (round-robin batches, paper default scheduling)
    def select(self, rnd: int) -> List[int]:
        n = self.cfg.n_clients
        k = self.cfg.clients_per_round
        start = (rnd * k) % n
        return [(start + i) % n for i in range(k)]

    # ---- round stages, shared by the synchronous and streaming loops ----

    def _apply_drift(self, rnd: int, users, specs) -> None:
        # context / hardware drift (paper §III-A interview triggers 2/3):
        # users move devices, schedules shift, batteries drain — changed
        # clients get re-profiled by the planner's next interview pass.
        drift_rng = round_drift_rng(self.cfg.seed, rnd)
        n_context_changes = sum(drift_user(u, drift_rng) for u in users)
        n_hw_changes = sum(drift_device(s, drift_rng) for s in specs)
        self.last_drift = (n_context_changes, n_hw_changes)

    def _plan(self, users, specs):
        # multi-client quantization planning (profiling pipeline):
        # cohort-batched — one RAG engine query per store for the whole
        # round instead of a per-client scan (DESIGN.md §10)
        decisions = plan_round(self.planner.plan_cohort(users, specs))
        bits = {d.user_id: d.bits for d in decisions}
        return decisions, bits

    def _train_cohort(self, decisions, ids: List[int], rnd: int, sr_seed,
                      chan_state=None):
        """Local training at the planned precision (stragglers drop out).

        Returns (deltas, weights, losses, active_ids, row_gains) with
        ``deltas[j]`` packed for uplink row j — the cohort order both
        round loops fold in. ``chan_state``: this round's sampled
        ``channel.ChannelState`` over the cohort (None = ideal channel);
        truncated clients are planned around — they skip local training
        entirely (the server knows they cannot invert their channel this
        round) — and ``row_gains[j]`` is row j's effective receive gain,
        aligned with ``deltas`` (None when ideal).
        """
        deltas, weights, losses, active_ids = [], [], [], []
        row_gains: Optional[List[float]] = None
        gains_np = habs_np = None
        if chan_state is not None:
            row_gains = []
            gains_np = np.asarray(jax.device_get(chan_state.gains))
            habs_np = np.asarray(jax.device_get(chan_state.habs))
        drop_rng = round_rng(self.cfg.seed, rnd)
        for pos, (d, i) in enumerate(zip(decisions, ids)):
            if gains_np is not None and gains_np[pos] <= 0.0:
                continue  # deep fade: truncated, planned around
            if self.cfg.dropout_prob and drop_rng.rand() < self.cfg.dropout_prob:
                continue  # straggler: never reports this round
            chan_kw = {}
            if gains_np is not None:
                chan_kw = dict(channel_gain=float(gains_np[pos]),
                               channel_habs=float(habs_np[pos]))
            delta, m = self.clients[i].local_update(
                self.params,
                d.bits,
                local_steps=self.cfg.local_steps,
                local_batch=self.cfg.local_batch,
                lr=self.cfg.lr,
                seed=self.cfg.seed * 97 + rnd,
                fedprox_mu=self.cfg.fedprox_mu,
                layout=self.layout,
                sr_seed=sr_seed,
                uplink_row=len(deltas),
                quant_block=self.cfg.quant_block,
                **chan_kw,
            )
            deltas.append(delta)
            if row_gains is not None:
                row_gains.append(m["channel_gain"])
            # FedAvg weight = samples x estimated contribution C_q (the
            # strategy's lever: class-equal upweights minority-rich
            # clients' updates, majority-centric the reverse; plain
            # fedavg has C_q == quantity x precision-quality only).
            contrib = 1.0
            if d.levels:
                sel = next((l for l in d.levels if l.bits == d.bits), None)
                if sel is not None:
                    contrib = sel.contribution
            weights.append(m["n_samples"] * contrib)
            losses.append(m["loss_last"])
            active_ids.append(i)
        return deltas, weights, losses, active_ids, row_gains

    def _sample_round_channel(self, round_key, ids: List[int]):
        """Sample this round's physical channel over the selected cohort.

        Drawn over the FULL cohort (before dropouts) so barrier and
        streaming rounds share the same realisation for the same round
        key and a client's draw doesn't depend on who else dropped.
        Records the realised radio state on each ``DeviceSpec``
        (``channel_snr_db`` EMA + running ``truncation_rate``) — the
        profiling features the RAG planner sees next round. Returns the
        ``ChannelState`` or None on the ideal channel.
        """
        if self.channel is None:
            return None
        with obs.span("channel_sample", cohort=len(ids)):
            state = self.channel.sample(round_key, len(ids))
        snr = np.asarray(jax.device_get(state.snr_db(self.cfg.snr_db)))
        trunc = np.asarray(jax.device_get(state.truncated))
        for pos, i in enumerate(ids):
            hist = self._chan_hist.setdefault(i, [0, 0])
            hist[0] += int(trunc[pos])
            hist[1] += 1
            spec = self.fleet[i]
            spec.truncation_rate = hist[0] / hist[1]
            prev = spec.channel_snr_db
            spec.channel_snr_db = (
                float(snr[pos]) if prev is None
                else 0.7 * prev + 0.3 * float(snr[pos])
            )
        return state

    def _apply_update(self, agg: Pytree, round_key) -> None:
        """Server optimizer step + compressed downlink broadcast (§13).

        FedAvgM momentum and the param update run on the flat f32 master
        vector (same float ops, in the same order, as the pre-§13
        per-leaf ``tree.map`` — packing is a concat). With
        ``FLConfig.quantize_server_state`` the velocity is *stored* bf16
        (0.5x f32 resident bytes) and dequantized to f32 for the math.

        The broadcast then goes through the same wire codec as the
        uplink (``core/wire.py``): f32 passthrough (``downlink_bits`` >=
        32) ships the absolute params vector — byte-for-byte today's
        broadcast, and the reconstruction is exactly the master; a
        quantized downlink encodes the delta against the fleet's current
        replica ONCE with the round's downlink dither seed
        (``ota.derive_dl_seed``), and every client decodes the same row
        to bit-identical params. The server adopts the reconstruction as
        ``self.params``, so the quantization residual stays in
        ``master - bcast`` and rides the next round's broadcast.
        """
        with obs.span("optimizer"):
            u = packing.pack(agg, self.layout)
            if self.cfg.server_momentum > 0.0:
                if not hasattr(self, "_velocity"):
                    self._velocity = jnp.zeros_like(u, jnp.float32)
                v = (
                    self.cfg.server_momentum * self._velocity.astype(jnp.float32)
                    + u
                )
                self._velocity = (
                    v.astype(jnp.bfloat16)
                    if self.cfg.quantize_server_state
                    else v
                )
                u = v
            self._master = self._master + u

        with obs.span("broadcast_encode", bits=self.cfg.downlink_bits):
            if packing.wire_kind(self.cfg.downlink_bits) == "float32":
                payload = self._master  # absolute params: passthrough oracle
            else:
                payload = self._master - self._bcast
            row = wire.encode_row(
                payload,
                self.cfg.downlink_bits,
                ota.derive_dl_seed(round_key),
                0,
                block=self.cfg.downlink_block,
            )
            self._bcast = wire.decode_broadcast(row, self._bcast)
            self.last_broadcast = row
            self.last_downlink_bytes = row.wire_nbytes
            self.params = packing.unpack(self._bcast, self.layout)

    @property
    def server_state_nbytes(self) -> int:
        """Resident bytes of the server optimizer state (0 before any
        momentum step; bf16 halves it under ``quantize_server_state``)."""
        v = getattr(self, "_velocity", None)
        return 0 if v is None else state_nbytes(v)

    def _observe_feedback(self, decisions, users, specs):
        # feedback: realised satisfaction -> RAG databases
        sats, energies = [], []
        for d, u, s in zip(decisions, users, specs):
            sat = satisfaction_score(u, s, d.bits)
            perf = true_performance(u, s, d.bits)
            self.planner.observe_feedback(u, s, d.bits, sat, perf)
            sats.append(sat)
            energies.append(perf["energy"])
        return sats, energies

    def run_round(self, rnd: int) -> RoundLog:
        # The whole round runs under one ``round`` span; each pipeline
        # stage gets its own nested span (DESIGN.md §14) — same span
        # names as the streaming loop, so traces from either engine
        # line up in one Perfetto view.
        with obs.span("round", round=rnd):
            ids = self.select(rnd)
            users = [self.users[i] for i in ids]
            specs = [self.fleet[i] for i in ids]
            with obs.span("plan", cohort=len(ids)):
                self._apply_drift(rnd, users, specs)
                decisions, bits = self._plan(users, specs)

            # The round key is fixed before the client loop so clients can
            # quantize + bit-pack their uplinks at the edge with the round's
            # shared dither stream (ota.derive_sr_seed); the server only ever
            # sees PackedRow wire rows, never the f32 (K, M) matrix.
            round_key = jax.random.key(self.cfg.seed * 131 + rnd)
            sr_seed = ota.derive_sr_seed(round_key)
            chan_state = self._sample_round_channel(round_key, ids)
            with obs.span("client_train"):
                deltas, weights, losses, active_ids, row_gains = (
                    self._train_cohort(decisions, ids, rnd, sr_seed, chan_state)
                )
            if not deltas:  # everyone dropped (or truncated): skip aggregation
                return self._log_round(
                    RoundLog(rnd, bits, 0.0, 0.0, 0, float("nan"))
                )

            # ---- mixed-precision OTA aggregation: the clients' quantized,
            # bit-packed wire rows go straight into the fused dequant +
            # superpose data plane (grouped per storage class, DESIGN.md §5).
            # Under the fading channel the reporting rows' effective gains
            # ride inside the fused pass (gains=, DESIGN.md §12).
            agg, info = ota.ota_aggregate_packed(
                round_key,
                deltas,
                [bits[self.users[i].user_id] for i in active_ids],
                weights,
                self.layout,
                ota.OTAConfig(snr_db=self.cfg.snr_db),
                gains=None
                if row_gains is None
                else jnp.asarray(row_gains, jnp.float32),
                mesh=self.mesh,
            )
            self.last_uplink_bytes = info["uplink_bytes"]
            self._apply_update(agg, round_key)
            info.downlink_bytes = self.last_downlink_bytes
            with obs.span("feedback"):
                sats, energies = self._observe_feedback(decisions, users, specs)

            return self._log_round(
                RoundLog(
                    round=rnd,
                    bits=bits,
                    mean_satisfaction=float(np.mean(sats)),
                    mean_energy=float(np.mean(energies)),
                    n_participating=info["n_participating"],
                    train_loss=float(np.mean(losses)),
                    uplink_bytes=info["uplink_bytes"],
                    downlink_bytes=self.last_downlink_bytes,
                )
            )

    def run(self, n_rounds: Optional[int] = None, *, verbose: bool = False):
        for r in range(n_rounds or self.cfg.n_rounds):
            log = self.run_round(r)
            if verbose:
                print(
                    f"round {r:3d} loss={log.train_loss:.3f} "
                    f"sat={log.mean_satisfaction:.3f} "
                    f"energy={log.mean_energy:.3f} "
                    f"clients={log.n_participating}"
                )
        return self.round_logs

    # ---- evaluation (word/char accuracy + CTC loss per category, Fig. 4)
    def evaluate(
        self,
        eval_set: Optional[List[Utterance]] = None,
        batch: int = 24,
        with_loss: bool = False,
    ) -> Dict[str, float]:
        eval_set = eval_set or make_eval_set(seed=self.cfg.seed + 999)
        correct: Dict[str, int] = {}
        total: Dict[str, int] = {}
        loss_sum: Dict[str, float] = {}
        loss_n: Dict[str, int] = {}
        from repro.models.deepspeech2 import ctc_loss, ds2_logits
        import jax.numpy as jnp

        for i in range(0, len(eval_set), batch):
            chunk = eval_set[i : i + batch]
            if len(chunk) < batch:  # keep shapes static for the jit cache
                chunk = list(chunk) + [chunk[-1]] * (batch - len(chunk))
            b = batchify(chunk, max_frames=320, max_labels=40)
            ids = ds2_greedy_decode(
                self.model_params_fn(), jnp.asarray(b["frames"]), self.arch
            )
            ids = np.asarray(ids)
            if with_loss:
                # per-utterance CTC loss (the accuracy metric is blind
                # during CTC's early blank-collapse phase; loss is not)
                lp = ds2_logits(
                    self.model_params_fn(), jnp.asarray(b["frames"]), self.arch
                )
                in_len = jnp.minimum(jnp.asarray(b["frame_len"]) // 4, lp.shape[1])
                for j, u in enumerate(chunk):
                    lj = float(
                        ctc_loss(
                            lp[j : j + 1],
                            jnp.asarray(b["labels"][j : j + 1]),
                            in_len[j : j + 1],
                            jnp.asarray(b["label_len"][j : j + 1]),
                        )
                    )
                    loss_sum[u.category] = loss_sum.get(u.category, 0.0) + lj
                    loss_n[u.category] = loss_n.get(u.category, 0) + 1
            for j, u in enumerate(chunk):
                # char accuracy: collapse decoded, compare to reference
                dec = [t for t in ids[j] if t != 0]
                ref = list(u.label_ids)
                n = max(len(ref), 1)
                # simple alignment-free prefix match score
                m = sum(1 for a, b_ in zip(dec, ref) if a == b_)
                correct[u.category] = correct.get(u.category, 0) + m
                total[u.category] = total.get(u.category, 0) + n
        out = {c: correct.get(c, 0) / max(total.get(c, 1), 1) for c in total}
        if with_loss:
            for c in loss_sum:
                out["loss_" + c] = loss_sum[c] / max(loss_n[c], 1)
        return out

    def model_params_fn(self):
        return self.params


# ---------------------------------------------------------------------------
# streaming rounds: event-driven buffered aggregation (DESIGN.md §11)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StreamPlan:
    """One round's arrival plan: who folds when (``plan_stream``).

    ``on_time``/``late``/``lost`` partition the uplink-row indices;
    ``staleness`` is aligned with ``late``. ``t_trigger`` is when the
    aggregation fires (cohort-fill or deadline, whichever first);
    ``t_close`` is when the round actually ends — the trigger, or the
    last counted late arrival inside the grace window.
    """

    on_time: Tuple[int, ...]
    late: Tuple[int, ...]
    lost: Tuple[int, ...]
    staleness: Tuple[float, ...]
    t_trigger: float
    t_close: float

    @property
    def counted(self) -> Tuple[int, ...]:
        """All folded row indices, in cohort (uplink-row) order."""
        return tuple(sorted(self.on_time + self.late))


def plan_stream(
    times: Sequence[float],
    *,
    fill: int,
    deadline: Optional[float] = None,
    grace: float = 0.0,
    gamma: float = 0.5,
) -> StreamPlan:
    """Plan one buffered round from simulated arrival times.

    ``times[j]`` is uplink row j's arrival (seconds; ``inf`` = never
    reports). The aggregation fires at the earlier of the ``fill``-th
    arrival (cohort-fill) and ``deadline``; if neither ever happens
    (fill target unreachable, no deadline) it degenerates to the
    synchronous barrier and fires at the last finite arrival. Rows
    landing within ``grace`` seconds after the trigger fold in late with
    the ``core.ota.staleness_weights`` discount ``gamma ** (lag /
    grace)``; later (or never-arriving) rows are lost.
    """
    t = [float(x) for x in times]
    finite = sorted(x for x in t if math.isfinite(x))
    t_fill = finite[fill - 1] if 0 < fill <= len(finite) else math.inf
    t_trigger = t_fill if deadline is None else min(t_fill, float(deadline))
    if not math.isfinite(t_trigger):
        t_trigger = finite[-1] if finite else 0.0
    g = max(float(grace), 1e-9)
    on_time, late, lost, stale = [], [], [], []
    for j, x in enumerate(t):
        if x <= t_trigger:
            on_time.append(j)
        elif x <= t_trigger + grace:
            late.append(j)
            stale.append(min(1.0, max(min(gamma, 1.0), gamma ** ((x - t_trigger) / g))))
        else:
            lost.append(j)
    t_close = max([t_trigger] + [t[j] for j in late])
    return StreamPlan(
        tuple(on_time), tuple(late), tuple(lost), tuple(stale), t_trigger, t_close
    )


@dataclasses.dataclass
class StreamRoundLog(RoundLog):
    sim_seconds: float = 0.0  # simulated wall-clock of the round
    n_on_time: int = 0
    n_late: int = 0
    n_lost: int = 0

    def publish(self, registry=None) -> "StreamRoundLog":
        m = registry or obs.metrics.REGISTRY
        super().publish(m)
        m.inc("stream.on_time", self.n_on_time)
        m.inc("stream.late", self.n_late)
        m.inc("stream.lost", self.n_lost)
        m.set_gauge("stream.sim_seconds", self.sim_seconds)
        return self


class StreamingFLServer(FLServer):
    """Event-driven buffered round loop (FedBuff-style, DESIGN.md §11).

    Same select/drift/plan/train stages as ``FLServer`` (identical seeded
    draws), but instead of the synchronous barrier every uplink gets a
    simulated arrival time (``LatencyModel``) and the round is an event
    queue: aggregation triggers on cohort-fill (``fill_fraction``) or
    ``deadline_s``, rows inside ``grace_s`` after the trigger fold in
    with the ``staleness_gamma`` discount, and everything folds into one
    persistent ``ota.OtaAccumulator``. The channel draw + weight
    renormalisation run once, at trigger time, over the full counted
    arrival set in cohort order — so with the defaults (full fill, no
    deadline, no latency dropouts) the round is bit-identical to
    ``FLServer.run_round``: the synchronous path is the oracle.
    """

    def __init__(
        self,
        fl_cfg: FLConfig,
        arch: Optional[ArchConfig] = None,
        *,
        shard_size: int = 24,
        fill_fraction: float = 1.0,
        deadline_s: Optional[float] = None,
        grace_s: float = 0.0,
        staleness_gamma: float = 0.5,
        latency: Optional[LatencyModel] = None,
    ):
        super().__init__(fl_cfg, arch, shard_size=shard_size)
        self.fill_fraction = fill_fraction
        self.deadline_s = deadline_s
        self.grace_s = grace_s
        self.staleness_gamma = staleness_gamma
        self.latency = latency if latency is not None else LatencyModel()

    def _sample_arrivals(self, deltas, active_ids: List[int], rnd: int) -> List[float]:
        """Simulated arrival time per uplink row (inf = never reports)."""
        lat_rng = round_rng(self.cfg.seed, rnd, salt=4099)
        times = []
        for r, i in zip(deltas, active_ids):
            t = self.latency.sample(self.fleet[i], lat_rng, uplink_bytes=r.wire_nbytes)
            if self.latency.dropped(self.fleet[i], lat_rng):
                t = math.inf
            times.append(t)
        return times

    def run_round(self, rnd: int) -> StreamRoundLog:
        # Same span names as the synchronous loop (DESIGN.md §14): the
        # arrival simulation and wave bookkeeping live inside the shared
        # stage spans, so a no-deadline streaming trace and a barrier
        # trace show the identical pipeline.
        with obs.span("round", round=rnd):
            return self._run_round_inner(rnd)

    def _run_round_inner(self, rnd: int) -> StreamRoundLog:
        ids = self.select(rnd)
        users = [self.users[i] for i in ids]
        specs = [self.fleet[i] for i in ids]
        with obs.span("plan", cohort=len(ids)):
            self._apply_drift(rnd, users, specs)
            decisions, bits = self._plan(users, specs)

        round_key = jax.random.key(self.cfg.seed * 131 + rnd)
        sr_seed = ota.derive_sr_seed(round_key)
        chan_state = self._sample_round_channel(round_key, ids)
        with obs.span("client_train"):
            deltas, weights, losses, active_ids, row_gains = self._train_cohort(
                decisions, ids, rnd, sr_seed, chan_state
            )
        if not deltas:  # everyone dropped in training: skip aggregation
            return self._log_round(
                StreamRoundLog(rnd, bits, 0.0, 0.0, 0, float("nan"))
            )

        # ---- arrival simulation + round plan (trigger/late/lost)
        times = self._sample_arrivals(deltas, active_ids, rnd)
        n = len(deltas)
        fill = (
            n
            if self.fill_fraction >= 1.0
            else max(1, math.ceil(self.fill_fraction * n))
        )
        plan = plan_stream(
            times,
            fill=fill,
            deadline=self.deadline_s,
            grace=self.grace_s,
            gamma=self.staleness_gamma,
        )
        self.last_times, self.last_plan = times, plan  # introspection
        counted = list(plan.counted)
        if not counted:  # every uplink lost in the air: skip aggregation
            return self._log_round(
                StreamRoundLog(
                    rnd,
                    bits,
                    0.0,
                    0.0,
                    0,
                    float("nan"),
                    sim_seconds=plan.t_close,
                    n_lost=n,
                )
            )

        # ---- channel + weight renormalisation over the counted set, in
        # cohort order, at trigger time (one draw per round — the same
        # key split as the synchronous path, ota.round_channel). Under
        # the fading channel the legacy coin-flip is replaced by the
        # realised gains: truncated rows never trained (planned around),
        # so every counted row has gain > 0; weights renormalise over
        # the counted set and the gains ride inside the fused fold.
        ocfg = ota.OTAConfig(snr_db=self.cfg.snr_db)
        w_counted = jnp.asarray([weights[j] for j in counted], jnp.float32)
        if row_gains is None:
            g_counted = None
            habs, participate, w = ota.round_channel(
                round_key, w_counted, cfg=ocfg)
        else:
            g_counted = jnp.asarray(
                [row_gains[j] for j in counted], jnp.float32)
            participate = g_counted > 0
            w = chanmod.combine_weights(w_counted, g_counted)

        # ---- fold arrivals into the persistent accumulator: the on-time
        # wave at the trigger, then the staleness-discounted late wave
        pos = {j: p for p, j in enumerate(counted)}
        acc = ota.OtaAccumulator(self.layout, ocfg, mesh=self.mesh)

        def _gsel(idx):
            if g_counted is None:
                return None
            return g_counted[jnp.asarray([pos[j] for j in idx], jnp.int32)]

        if plan.late:
            stale = dict(zip(plan.late, plan.staleness))
            on_sorted, late_sorted = sorted(plan.on_time), sorted(plan.late)
            w_on = w[jnp.asarray([pos[j] for j in on_sorted], jnp.int32)]
            w_late = w[jnp.asarray([pos[j] for j in late_sorted], jnp.int32)]
            acc.fold([deltas[j] for j in on_sorted], w_on,
                     gains=_gsel(on_sorted))
            acc.fold(
                [deltas[j] for j in late_sorted],
                w_late,
                staleness=[stale[j] for j in late_sorted],
                gains=_gsel(late_sorted),
            )
        else:  # single wave: identical fold to the synchronous barrier
            acc.fold([deltas[j] for j in counted], w, gains=g_counted)
        agg, info = acc.finalize(round_key)
        self.last_uplink_bytes = info["uplink_bytes"]
        self._apply_update(agg, round_key)
        info.downlink_bytes = self.last_downlink_bytes
        with obs.span("feedback"):
            sats, energies = self._observe_feedback(decisions, users, specs)

        return self._log_round(
            StreamRoundLog(
                round=rnd,
                bits=bits,
                mean_satisfaction=float(np.mean(sats)),
                mean_energy=float(np.mean(energies)),
                n_participating=int(jax.device_get(participate).sum()),
                train_loss=float(np.mean([losses[j] for j in counted])),
                uplink_bytes=info["uplink_bytes"],
                downlink_bytes=self.last_downlink_bytes,
                sim_seconds=plan.t_close,
                n_on_time=len(plan.on_time),
                n_late=len(plan.late),
                n_lost=len(plan.lost),
            )
        )
