from repro.fl.client import FLClient, LatencyModel  # noqa: F401
from repro.fl.server import (  # noqa: F401
    FLServer,
    RoundLog,
    StreamingFLServer,
    StreamPlan,
    StreamRoundLog,
    make_planner,
    plan_stream,
    round_rng,
)
