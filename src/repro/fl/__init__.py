from repro.fl.client import FLClient  # noqa: F401
from repro.fl.server import FLServer, RoundLog, make_planner  # noqa: F401
