"""FL client: local training at the planned precision level.

A client owns a simulated user (ground truth), a device spec, and a data
shard. ``local_update`` runs local SGD steps with the model fake-quantized
to the planned bits (STE gradients) and returns the parameter delta — the
thing the OTA channel superposes. With a ``layout`` the delta is returned
already flat-packed (``core.packing``): the client is the one that
modulates its update onto the analog symbol stream, so the pytree never
crosses the client/server boundary and the server stacks rows straight
into the (K, M) aggregation matrix. With the round's dither seed as well,
the client also *quantizes and bit-packs* its row through the symmetric
wire codec (``wire.encode_row`` -> ``packing.PackedRow``): a 4-bit
client's uplink is two symbols per byte + one f32 scale, 1/8 the f32 row
(DESIGN.md §6). The same codec decodes the server's compressed downlink
broadcast (``wire.decode_broadcast``, DESIGN.md §13).

The module also hosts the seeded ``LatencyModel`` — per-client lognormal
compute + uplink delay derived from the ``DeviceSpec`` — that gives every
uplink a simulated arrival time for the streaming round loop
(DESIGN.md §11).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import packing
from repro.core.profiling.hardware import DeviceSpec
from repro.core.profiling.users import UserTruth
from repro.data.voice import ClientShard, batchify
from repro.launch.steps import make_quantized_train_step
from repro.models.registry import Model
from repro.optim import sgd

Pytree = Any


# process-wide compiled-step cache: all clients at the same (arch, bits,
# lr) share one XLA executable — compile once, reuse across the federation.
_STEP_CACHE: Dict[Tuple[str, int, float], Tuple[Callable, Any]] = {}


# simulated uplink rate per device class (Mbit/s). ``DeviceSpec`` carries
# no radio field, so the device class is the proxy: flagships and laptops
# on good WiFi/5G, IoT hubs on constrained links.
UPLINK_MBPS: Dict[str, float] = {
    "flagship_phone": 20.0,
    "midrange_phone": 10.0,
    "smart_speaker": 8.0,
    "iot_hub": 2.0,
    "laptop": 40.0,
}


@dataclasses.dataclass(frozen=True)
class LatencyModel:
    """Seeded per-round client latency + dropout simulation (DESIGN.md
    §11).

    Gives every uplink an arrival *time* so the streaming round loop has
    an order and a clock. Compute time is the round's training work over
    the device's sustained flops (``DeviceSpec.cpu_gflops``) times a
    lognormal multiplier — ``sigma`` tunes the straggler tail, with
    p95/p50 = exp(1.645 * sigma). Uplink time is the packed row's wire
    bytes over the device class's link rate (``UPLINK_MBPS``) with its
    own (lighter) lognormal jitter. Low-battery devices throttle by
    ``low_battery_slowdown``. ``drop_prob`` is the per-round
    never-reports probability (doubled on low battery) — the scheduling
    simulation's dropout knob, on top of ``FLConfig.dropout_prob`` which
    the training loop itself applies. All draws come from the caller's
    ``numpy.random.RandomState``, so a seeded round replays exactly.
    """

    work_flops: float = 5e9  # proxy for local_steps x batch x model cost
    sigma: float = 0.6  # compute lognormal spread (straggler tail)
    net_sigma: float = 0.25  # uplink jitter
    low_battery_slowdown: float = 2.0
    drop_prob: float = 0.0

    @classmethod
    def with_tail(cls, p95_over_p50: float, **kw) -> "LatencyModel":
        """Model whose compute-latency p95/p50 ratio is the given tail."""
        import math

        return cls(sigma=math.log(p95_over_p50) / 1.645, **kw)

    def p95_over_p50(self) -> float:
        return float(np.exp(1.645 * self.sigma))

    def sample(
        self, spec: DeviceSpec, rng: np.random.RandomState, *, uplink_bytes: int
    ) -> float:
        """One arrival latency (seconds) for this device and uplink."""
        compute = self.work_flops / (spec.cpu_gflops * 1e9)
        if spec.power_state == "low_battery":
            compute *= self.low_battery_slowdown
        compute *= rng.lognormal(0.0, self.sigma)
        rate = UPLINK_MBPS.get(spec.device_class, 10.0) * 1e6 / 8.0
        uplink = (uplink_bytes / rate) * rng.lognormal(0.0, self.net_sigma)
        return float(compute + uplink)

    def dropped(self, spec: DeviceSpec, rng: np.random.RandomState) -> bool:
        """Whether this client silently never reports this round."""
        p = self.drop_prob
        if spec.power_state == "low_battery":
            p = min(1.0, 2.0 * p)
        return p > 0 and bool(rng.rand() < p)


@dataclasses.dataclass
class FLClient:
    user: UserTruth
    spec: DeviceSpec
    shard: ClientShard
    model: Model

    def _step_fn(
        self, bits: int, lr: float, fedprox_mu: float = 0.0
    ) -> Tuple[Callable, Any]:
        key = (self.model.cfg.name, bits, lr, fedprox_mu)
        if key not in _STEP_CACHE:
            opt = sgd(lr)
            step = make_quantized_train_step(
                self.model, opt, bits, fedprox_mu=fedprox_mu
            )
            _STEP_CACHE[key] = (jax.jit(step), opt)
        return _STEP_CACHE[key]

    def local_update(
        self,
        global_params: Pytree,
        bits: int,
        *,
        local_steps: int = 4,
        local_batch: int = 8,
        lr: float = 5e-4,
        seed: int = 0,
        max_frames: int = 320,
        max_labels: int = 40,
        fedprox_mu: float = 0.0,
        layout: Optional[packing.Layout] = None,
        sr_seed: Optional[jnp.ndarray] = None,
        uplink_row: int = 0,
        quant_block: int = 0,
        channel_gain: Optional[float] = None,
        channel_habs: Optional[float] = None,
    ) -> Tuple[Any, Dict[str, float]]:
        """Run local steps; return (delta, metrics).

        With ``layout`` alone, delta is the flat-packed (padded_size,) f32
        row ready to stack into the OTA aggregation matrix. With
        ``sr_seed`` too (the round dither seed, ``ota.derive_sr_seed``;
        ``uplink_row`` = this client's row in the round cohort), delta is
        the quantized+bit-packed wire row (``packing.PackedRow``) — the
        client modulates its own uplink, at ``bits``, and only
        sub-byte-packed symbols plus the scale vector cross to the
        server. ``quant_block`` > 0 quantizes with blockwise scales (one
        f32 per ``quant_block`` symbols, the round config's
        ``FLConfig.quant_block``; 0 = one per-update scale). Without
        ``layout``: the parameter-delta pytree (legacy shape).

        ``channel_gain``/``channel_habs``: this round's realised channel
        state for the client (``core.channel``, DESIGN.md §12) — echoed
        into the returned metrics as uplink metadata, the per-round
        radio report that rides alongside the packed row.
        """
        jitted, opt = self._step_fn(bits, lr, fedprox_mu)
        state = {
            "params": global_params,
            "opt": opt.init(global_params),
            "step": jnp.zeros((), jnp.int32),
        }
        if fedprox_mu > 0.0:
            state["anchor"] = global_params
        rng = np.random.RandomState(seed * 1009 + self.user.user_id)
        losses = []
        utts = self.shard.utterances
        for s in range(local_steps):
            idx = rng.randint(0, len(utts), size=min(local_batch, len(utts)))
            batch = batchify(
                [utts[i] for i in idx], max_frames=max_frames, max_labels=max_labels
            )
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            state, metrics = jitted(state, batch)
            losses.append(float(metrics["loss"]))
        delta = jax.tree.map(
            lambda new, old: (new.astype(jnp.float32) - old.astype(jnp.float32)),
            state["params"],
            global_params,
        )
        if layout is not None:
            delta = packing.pack(delta, layout)
            if sr_seed is not None:
                from repro.core import wire

                with obs.span("uplink_encode", bits=bits):
                    delta = wire.encode_row(
                        delta, bits, sr_seed, uplink_row, block=quant_block
                    )
        metrics = {
            "loss_first": losses[0],
            "loss_last": losses[-1],
            "n_samples": len(utts),
        }
        if channel_gain is not None:
            metrics["channel_gain"] = float(channel_gain)
        if channel_habs is not None:
            metrics["channel_habs"] = float(channel_habs)
        return delta, metrics
