"""The LLM interview agent — SimLLM edition.

The paper drives profiling through an LLM-powered chat interface
(§III-A "User Profiling Frontend", §III-B "hybrid conversational
interface"). Offline we replace the hosted LLM with a deterministic
semantic parser over a synonym lexicon, exercised against *templated
utterances generated from each user's hidden ground truth plus noise*:

    ground truth --(templating + chattiness dropout)--> transcript
    transcript  --(SimLLM parse)--> InferredProfile

The interface (``InterviewAgent.interview``) is exactly what an
API-backed agent would implement — swap ``SimLLM`` for a real model and
nothing upstream changes. Crucially the parser is *imperfect on purpose*:
users may not mention factors (chattiness), wordings are ambiguous, and
the resulting inferred profile carries per-field confidence — the RAG
retrieval (§III-B2) exists to fill exactly these gaps.
"""

from __future__ import annotations

import dataclasses
import random
from typing import Dict, List, Optional, Tuple

from repro.core.profiling.users import FACTORS, UserTruth

# ---------------------------------------------------------------------------
# utterance templates (generation side)
# ---------------------------------------------------------------------------

LOCATION_PHRASES = {
    "bedroom": ["it's in my bedroom", "sits on my nightstand", "bedroom device"],
    "living_room": [
        "it's in the living room",
        "next to the TV",
        "the kids use it in the lounge",
    ],
    "kitchen": ["kitchen counter", "I use it while cooking", "it's in the kitchen"],
    "office": ["on my office desk", "I use it at work", "study room"],
    "outdoor": ["I mostly use it outside", "on the patio", "in the garden"],
}
TIME_PHRASES = {
    "daytime": ["mostly during the day", "throughout the workday", "daytime mostly"],
    "nighttime": ["usually at night", "before bed", "late evenings"],
}
FREQ_PHRASES = {
    "low": ["only now and then", "a couple times a week", "rarely"],
    "medium": ["a few times a day", "pretty regularly", "daily"],
    "high": ["all the time", "constantly", "dozens of times a day"],
}
SENSITIVITY_PHRASES = {
    "accuracy": [
        "it keeps mishearing me",
        "I need it to get things right",
        "transcription mistakes drive me crazy",
        "accuracy matters most to me",
    ],
    "energy": [
        "the battery dies fast",
        "I worry about power usage",
        "it should be efficient",
        "battery life is my main concern",
    ],
    "latency": [
        "it feels sluggish",
        "I hate waiting for responses",
        "it must respond instantly",
        "speed is everything",
    ],
}
CATEGORY_PHRASES = {
    "entertainment": ["I mostly play music", "podcasts and radio"],
    "smart_home": [
        "controlling the lights",
        "smart home stuff",
        "thermostat and plugs",
    ],
    "general_query": ["asking questions", "weather and news"],
    "personal_request": ["reminders and my calendar", "personal lists"],
}

# ---------------------------------------------------------------------------
# lexicon (parsing side) — keyword -> (field, value, strength)
# ---------------------------------------------------------------------------

# keyword anchors are curated (not auto-split from the templates, so the
# parser genuinely has to generalise across phrasings):
LEXICON: List[Tuple[str, str, str, float]] = [
    ("bedroom", "location", "bedroom", 1.0),
    ("nightstand", "location", "bedroom", 0.9),
    ("living", "location", "living_room", 1.0),
    ("lounge", "location", "living_room", 0.9),
    ("tv", "location", "living_room", 0.6),
    ("kitchen", "location", "kitchen", 1.0),
    ("cooking", "location", "kitchen", 0.8),
    ("office", "location", "office", 1.0),
    ("desk", "location", "office", 0.7),
    ("work", "location", "office", 0.5),
    ("study", "location", "office", 0.8),
    ("outside", "location", "outdoor", 0.9),
    ("patio", "location", "outdoor", 0.9),
    ("garden", "location", "outdoor", 0.9),
    ("day", "time", "daytime", 0.7),
    ("workday", "time", "daytime", 0.9),
    ("night", "time", "nighttime", 0.9),
    ("bed", "time", "nighttime", 0.6),
    ("evenings", "time", "nighttime", 0.9),
    ("rarely", "frequency", "low", 1.0),
    ("now and then", "frequency", "low", 0.9),
    ("couple times a week", "frequency", "low", 1.0),
    ("regularly", "frequency", "medium", 0.8),
    ("few times a day", "frequency", "medium", 1.0),
    ("daily", "frequency", "medium", 0.7),
    ("all the time", "frequency", "high", 1.0),
    ("constantly", "frequency", "high", 1.0),
    ("dozens", "frequency", "high", 1.0),
    ("mishearing", "sens_accuracy", "", 1.0),
    ("get things right", "sens_accuracy", "", 0.9),
    ("mistakes", "sens_accuracy", "", 0.8),
    ("accuracy", "sens_accuracy", "", 1.0),
    ("battery", "sens_energy", "", 1.0),
    ("power usage", "sens_energy", "", 0.9),
    ("efficient", "sens_energy", "", 0.8),
    ("sluggish", "sens_latency", "", 0.9),
    ("waiting", "sens_latency", "", 0.8),
    ("instantly", "sens_latency", "", 1.0),
    ("speed", "sens_latency", "", 0.9),
    ("music", "cat_entertainment", "", 0.9),
    ("podcasts", "cat_entertainment", "", 0.9),
    ("radio", "cat_entertainment", "", 0.8),
    ("lights", "cat_smart_home", "", 0.9),
    ("smart home", "cat_smart_home", "", 1.0),
    ("thermostat", "cat_smart_home", "", 0.9),
    ("plugs", "cat_smart_home", "", 0.8),
    ("questions", "cat_general_query", "", 0.8),
    ("weather", "cat_general_query", "", 0.9),
    ("news", "cat_general_query", "", 0.8),
    ("reminders", "cat_personal_request", "", 0.9),
    ("calendar", "cat_personal_request", "", 0.9),
    ("lists", "cat_personal_request", "", 0.7),
]


@dataclasses.dataclass
class InferredProfile:
    """What the backend believes about a user after an interview."""

    user_id: int
    location: Optional[str] = None
    location_conf: float = 0.0
    time: Optional[str] = None
    time_conf: float = 0.0
    frequency: Optional[str] = None
    frequency_conf: float = 0.0
    # relative sensitivity signal strengths (unnormalised)
    sens: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {f: 0.0 for f in FACTORS}
    )
    category_signal: Dict[str, float] = dataclasses.field(default_factory=dict)

    def weights_estimate(self) -> Dict[str, float]:
        """Normalised sensitivity estimate; uniform prior when silent."""
        base = {f: 0.34 + self.sens.get(f, 0.0) for f in FACTORS}
        s = sum(base.values())
        return {f: v / s for f, v in base.items()}

    def features(self) -> Dict[str, float]:
        f: Dict[str, float] = {}
        if self.location:
            f["loc_" + self.location] = self.location_conf
        if self.time:
            f["time_" + self.time] = self.time_conf
        if self.frequency:
            f["freq_" + self.frequency] = self.frequency_conf
        for c, v in self.category_signal.items():
            f["cat_" + c] = v
        for fac, v in self.sens.items():
            if v > 0:
                f["sens_" + fac] = v
        return f


class SimLLM:
    """Deterministic stand-in for the hosted LLM: parse(transcript)->fields.

    A production deployment implements the same two methods with an actual
    chat model; the pipeline is agnostic (DESIGN.md §2).
    """

    def parse(self, transcript: str) -> InferredProfile:
        text = transcript.lower()
        prof = InferredProfile(user_id=-1)
        best: Dict[str, Tuple[str, float]] = {}
        for kw, field, value, strength in LEXICON:
            if kw in text:
                if field.startswith("sens_"):
                    fac = field[5:]
                    prof.sens[fac] = max(prof.sens[fac], strength)
                elif field.startswith("cat_"):
                    cat = field[4:]
                    prof.category_signal[cat] = max(
                        prof.category_signal.get(cat, 0.0), strength
                    )
                else:
                    cur = best.get(field)
                    if cur is None or strength > cur[1]:
                        best[field] = (value, strength)
        if "location" in best:
            prof.location, prof.location_conf = best["location"]
        if "time" in best:
            prof.time, prof.time_conf = best["time"]
        if "frequency" in best:
            prof.frequency, prof.frequency_conf = best["frequency"]
        return prof


class InterviewAgent:
    """Generates the (simulated) conversation and parses it.

    Three interview triggers per the paper §III-A: device initialisation,
    pre-aggregation feedback, and hardware-change updates. All flow
    through the same generate+parse path here.
    """

    def __init__(self, seed: int = 0):
        self.rng = random.Random(seed + 99)
        self.llm = SimLLM()

    def _utterance(self, user: UserTruth) -> str:
        rng = self.rng
        parts: List[str] = []

        def reveal():
            return rng.random() < user.chattiness

        if reveal():
            parts.append(rng.choice(LOCATION_PHRASES[user.location]))
        if reveal():
            parts.append(rng.choice(TIME_PHRASES[user.interaction_time]))
        if reveal():
            parts.append(rng.choice(FREQ_PHRASES[user.frequency]))
        # sensitivities mentioned proportionally to true weight
        for fac in FACTORS:
            if rng.random() < user.weights[fac] * 1.4 * user.chattiness:
                parts.append(rng.choice(SENSITIVITY_PHRASES[fac]))
        # mention dominant categories
        for cat, p in user.category_mix.items():
            if rng.random() < p * 1.2 * user.chattiness:
                parts.append(rng.choice(CATEGORY_PHRASES[cat]))
        if not parts:
            parts.append("it's fine I guess")
        return ". ".join(parts) + "."

    def interview(self, user: UserTruth) -> Tuple[str, InferredProfile]:
        transcript = self._utterance(user)
        prof = self.llm.parse(transcript)
        prof.user_id = user.user_id
        return transcript, prof

    def feedback_utterance(self, user: UserTruth, satisfaction: float) -> str:
        """Post-round feedback text, tone keyed to realised satisfaction."""
        rng = self.rng
        if satisfaction > 0.35:
            base = rng.choice(["works great", "very happy with it", "no complaints"])
        elif satisfaction > 0.1:
            base = rng.choice(["it's okay", "decent overall", "fine mostly"])
        else:
            dominant = max(user.weights, key=user.weights.get)
            base = rng.choice(SENSITIVITY_PHRASES[dominant])
        return base + "."
