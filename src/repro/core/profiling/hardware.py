"""Simulated client hardware fleet + hardware specification extractor.

The paper's backend has a "hardware specification extractor that collects
device hardware information based on availability and user privacy
settings". Here the fleet is simulated; the extractor exposes exactly the
fields a real agent could read (and respects a per-device privacy flag
that hides some of them, which the RAG retrieval then has to work around
— same failure mode as production).
"""

from __future__ import annotations

import dataclasses
import random
from typing import Dict, List, Optional, Tuple

DEVICE_CLASSES: Dict[str, Dict] = {
    # cpu_gflops ~ sustained fp32; energy_per_mac_pj at 32-bit
    "flagship_phone": dict(
        cpu_gflops=250.0,
        ram_gb=12,
        battery_mah=5000,
        supported_bits=(4, 8, 16, 32),
        energy_per_mac_pj=3.0,
    ),
    "midrange_phone": dict(
        cpu_gflops=80.0,
        ram_gb=6,
        battery_mah=4500,
        supported_bits=(4, 8, 16),
        energy_per_mac_pj=4.5,
    ),
    "smart_speaker": dict(
        cpu_gflops=25.0,
        ram_gb=2,
        battery_mah=0,  # mains
        supported_bits=(4, 8, 16),
        energy_per_mac_pj=6.0,
    ),
    "iot_hub": dict(
        cpu_gflops=8.0,
        ram_gb=1,
        battery_mah=2000,
        supported_bits=(4, 8),
        energy_per_mac_pj=8.0,
    ),
    "laptop": dict(
        cpu_gflops=600.0,
        ram_gb=16,
        battery_mah=8000,
        supported_bits=(4, 8, 16, 32),
        energy_per_mac_pj=2.0,
    ),
}

CLASS_MIX = [
    ("flagship_phone", 0.20),
    ("midrange_phone", 0.30),
    ("smart_speaker", 0.25),
    ("iot_hub", 0.15),
    ("laptop", 0.10),
]


@dataclasses.dataclass
class DeviceSpec:
    device_id: int
    device_class: str
    cpu_gflops: float
    ram_gb: float
    battery_mah: float
    supported_bits: Tuple[int, ...]
    energy_per_mac_pj: float
    power_state: str = "normal"  # normal | low_battery | charging
    privacy_hide_specs: bool = False
    # radio state observed by the server per round (core/channel.py,
    # DESIGN.md §12): EMA of the realised per-client receive SNR and the
    # running truncation rate. Server-side measurements, so they survive
    # the privacy flag (nothing the device has to disclose).
    channel_snr_db: Optional[float] = None
    truncation_rate: float = 0.0

    def features(self) -> Dict[str, float]:
        """Numeric feature dict for RAG keys (respecting privacy flag)."""
        if self.privacy_hide_specs:
            # only the coarse class survives privacy settings
            feats = {"class_" + self.device_class: 2.0}
        else:
            # class weighted up: device-class is the dominant predictor
            # of the quantization-performance deviations the HQP DB
            # exists to learn
            feats = {
                "class_" + self.device_class: 2.0,
                "cpu_gflops": self.cpu_gflops / 600.0,
                "ram_gb": self.ram_gb / 16.0,
                "battery": (self.battery_mah or 0) / 8000.0,
                "power_" + self.power_state: 0.5,
            }
        if self.channel_snr_db is not None:
            feats["channel_snr_db"] = self.channel_snr_db / 30.0
            feats["truncation_rate"] = self.truncation_rate
        return feats


def make_fleet(n: int, seed: int = 0) -> List[DeviceSpec]:
    rng = random.Random(seed)
    classes = [c for c, _ in CLASS_MIX]
    probs = [p for _, p in CLASS_MIX]
    fleet = []
    for i in range(n):
        cls = rng.choices(classes, probs)[0]
        base = DEVICE_CLASSES[cls]

        def jitter(v):
            return v * rng.uniform(0.85, 1.15)

        fleet.append(
            DeviceSpec(
                device_id=i,
                device_class=cls,
                cpu_gflops=jitter(base["cpu_gflops"]),
                ram_gb=base["ram_gb"],
                battery_mah=base["battery_mah"],
                supported_bits=base["supported_bits"],
                energy_per_mac_pj=jitter(base["energy_per_mac_pj"]),
                power_state=rng.choices(
                    ["normal", "low_battery", "charging"], [0.7, 0.15, 0.15]
                )[0],
                privacy_hide_specs=rng.random() < 0.1,
            )
        )
    return fleet


def hardware_tier(spec: DeviceSpec) -> str:
    """The unified baseline planner's tiering (hardware capability only)."""
    if spec.cpu_gflops >= 200:
        return "high"
    if spec.cpu_gflops >= 40:
        return "mid"
    return "low"


# unified planner's assignment: each tier runs at its hardware capability
# (a hardware-only planner has no signal that would justify down-bitting)
TIER_BITS = {"high": 16, "mid": 8, "low": 8}


def max_feasible_bits(spec: DeviceSpec) -> int:
    bits = max(spec.supported_bits)
    if spec.power_state == "low_battery":
        bits = min(bits, 8)
    return bits
