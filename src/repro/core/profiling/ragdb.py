"""RAG knowledge databases (the paper's §III-B2).

Two stores, both built on a feature-hashed vector index with cosine
retrieval (an embedding-model-backed store is a drop-in — the interface
is add/query):

- ``ContextQuantFeedbackDB``: archives (context features, assigned bits,
  realised feedback/satisfaction) per round — "semantic mappings between
  contextual factors and user factors".
- ``HardwareQuantPerfDB``: archives (hardware features, bits) ->
  measured (accuracy, energy, latency) — the quantization-performance
  trade-off store queried by hardware similarity.

Records append continuously ("facilitating continuous refinement").

Since PR 4 both databases ride the retrieval subsystem
(``repro.retrieval``, DESIGN.md §10): vectors live in a contiguous
arena slab and queries go through the batched engine — one call per
cohort (``query_batch``) instead of one numpy scan per client. The
neighbour-weighting estimators are exposed as ``*_from_hits`` functions
so the cohort-batched planner can score pre-fetched hit lists. The
legacy brute-force ``VectorStore`` stays as the arena's equivalence
oracle (same tie contract: descending similarity, ties by ascending
record index).
"""

from __future__ import annotations

import dataclasses
import hashlib
import math
from typing import Any, Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.retrieval.store import ArenaVectorStore

EMBED_DIM = 256

# neighbours fetched per store per query — the estimators' k = 8 times
# the 4x over-fetch the bit-distance weighting wants
RETRIEVE_K = 32


def _hash_idx(token: str) -> Tuple[int, float]:
    h = hashlib.blake2b(token.encode(), digest_size=8).digest()
    idx = int.from_bytes(h[:4], "little") % EMBED_DIM
    sign = 1.0 if h[4] & 1 else -1.0
    return idx, sign


def embed_features(features: Dict[str, float]) -> np.ndarray:
    """Feature-hash a {name: weight} dict into a unit vector."""
    v = np.zeros(EMBED_DIM, np.float32)
    for name, w in features.items():
        idx, sign = _hash_idx(name)
        v[idx] += sign * float(w)
    n = np.linalg.norm(v)
    return v / n if n > 0 else v


def embed_batch(features_list: Iterable[Dict[str, float]]) -> np.ndarray:
    """Embed a whole cohort's feature dicts into one (K, D) query batch."""
    return np.stack([embed_features(f) for f in features_list])


@dataclasses.dataclass
class Record:
    features: Dict[str, float]
    payload: Dict[str, Any]


class VectorStore:
    """Legacy brute-force store — the arena engine's equivalence oracle.

    Kept deliberately simple (one numpy scan per query) but with the two
    seed defects fixed: adds write into an amortized-doubling matrix
    instead of re-stacking O(N) vectors on every add -> query cycle, and
    a zero-norm query (empty/cancelled features) returns no hits instead
    of cosine-against-zeros.
    """

    def __init__(self):
        self._matrix = np.zeros((64, EMBED_DIM), np.float32)
        self._n = 0
        self._records: List[Record] = []

    def __len__(self) -> int:
        return self._n

    def add(self, features: Dict[str, float], payload: Dict[str, Any]) -> None:
        if self._n == self._matrix.shape[0]:
            grown = np.zeros((2 * self._n, EMBED_DIM), np.float32)
            grown[: self._n] = self._matrix
            self._matrix = grown
        self._matrix[self._n] = embed_features(features)
        self._records.append(Record(features, payload))
        self._n += 1

    def query(
        self, features: Dict[str, float], k: int = 8
    ) -> List[Tuple[float, Record]]:
        if not self._records:
            return []
        q = embed_features(features)
        if not np.any(q):  # zero-norm query guard
            return []
        sims = self._matrix[: self._n] @ q
        # independent of the engine's stable_topk on purpose — this is
        # the oracle, so it uses the plain brute-force specification of
        # the tie contract (stable sort: desc score, ties by asc index)
        idx = np.argsort(-sims, kind="stable")[: min(k, self._n)]
        return [(float(sims[i]), self._records[i]) for i in idx]


# ---------------------------------------------------------------------------
# neighbour-weighted estimators over hit lists
# ---------------------------------------------------------------------------


def satisfaction_from_hits(
    hits: List[Tuple[float, Record]], bits: int
) -> Optional[Tuple[float, float]]:
    """(estimate, confidence) for assigning ``bits`` given retrieved
    context hits.

    Retrieval is context-wide; matching-bit neighbours weigh fully,
    near-bit neighbours partially (quantization effects are smooth in
    log-bits).
    """
    if not hits:
        return None
    num = den = 0.0
    log_bits = math.log2(bits)
    for sim, rec in hits:
        if sim <= 0:
            continue
        # math.log2 over np.log2: these are python scalars in the
        # planner's per-level hot loop, where numpy scalar dispatch
        # dominated the profile
        db = abs(math.log2(rec.payload["bits"]) - log_bits)
        bit_w = max(0.0, 1.0 - 0.5 * db)
        w = sim * bit_w
        num += w * rec.payload["satisfaction"]
        den += w
    if den < 1e-6:
        return None
    conf = min(1.0, den / 3.0)
    return num / den, conf


def perf_from_hits(
    hits: List[Tuple[float, Record]], bits: int
) -> Optional[Dict[str, float]]:
    """Similarity-weighted perf estimate from matching-bit hits."""
    agg: Dict[str, float] = {}
    den = 0.0
    for sim, rec in hits:
        if sim <= 0 or rec.payload["bits"] != bits:
            continue
        for name, val in rec.payload["perf"].items():
            agg[name] = agg.get(name, 0.0) + sim * val
        den += sim
    if den < 1e-6:
        return None
    return {name: v / den for name, v in agg.items()}


# ---------------------------------------------------------------------------
# the arena-backed stores
# ---------------------------------------------------------------------------


class _FeatureArenaStore(ArenaVectorStore):
    """Feature-dict front end over the arena store (append-only; save /
    restore serialize the Record list through the ckpt layer)."""

    def __init__(self, *, storage: str = "f32"):
        super().__init__(
            EMBED_DIM,
            storage=storage,
            to_doc=dataclasses.asdict,
            from_doc=lambda d: Record(**d),
        )

    def add(self, features: Dict[str, float], payload: Dict[str, Any]) -> None:
        self.add_vec(embed_features(features), Record(features, payload))

    def query(
        self, features: Dict[str, float], k: int = 8
    ) -> List[Tuple[float, Record]]:
        q = embed_features(features)
        if not len(self) or not np.any(q):  # zero-norm query guard
            return []
        return self.query_vec(q, k)


class ContextQuantFeedbackDB(_FeatureArenaStore):
    """context/preference features + bits -> realised satisfaction feedback."""

    def add_feedback(
        self,
        features: Dict[str, float],
        bits: int,
        satisfaction: float,
        perf: Dict[str, float],
    ) -> None:
        self.add(
            features,
            {"bits": bits, "satisfaction": satisfaction, "perf": dict(perf)},
        )

    def estimate_satisfaction(
        self, features: Dict[str, float], bits: int, k: int = 8
    ) -> Optional[Tuple[float, float]]:
        return satisfaction_from_hits(self.query(features, k=k * 4), bits)


class HardwareQuantPerfDB(_FeatureArenaStore):
    """hardware features + bits -> measured perf dict."""

    def add_measurement(
        self, hw_features: Dict[str, float], bits: int, perf: Dict[str, float]
    ) -> None:
        self.add(hw_features, {"bits": bits, "perf": dict(perf)})

    def estimate_perf(
        self, hw_features: Dict[str, float], bits: int, k: int = 8
    ) -> Optional[Dict[str, float]]:
        return perf_from_hits(self.query(hw_features, k=k * 4), bits)
