"""RAG knowledge databases (the paper's §III-B2).

Two stores, both built on a feature-hashed vector index with cosine
retrieval (pure numpy; an embedding-model-backed store is a drop-in —
the interface is add/query):

- ``ContextQuantFeedbackDB``: archives (context features, assigned bits,
  realised feedback/satisfaction) per round — "semantic mappings between
  contextual factors and user factors".
- ``HardwareQuantPerfDB``: archives (hardware features, bits) ->
  measured (accuracy, energy, latency) — the quantization-performance
  trade-off store queried by hardware similarity.

Records append continuously ("facilitating continuous refinement").
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

EMBED_DIM = 256


def _hash_idx(token: str) -> Tuple[int, float]:
    h = hashlib.blake2b(token.encode(), digest_size=8).digest()
    idx = int.from_bytes(h[:4], "little") % EMBED_DIM
    sign = 1.0 if h[4] & 1 else -1.0
    return idx, sign


def embed_features(features: Dict[str, float]) -> np.ndarray:
    """Feature-hash a {name: weight} dict into a unit vector."""
    v = np.zeros(EMBED_DIM, np.float32)
    for name, w in features.items():
        idx, sign = _hash_idx(name)
        v[idx] += sign * float(w)
    n = np.linalg.norm(v)
    return v / n if n > 0 else v


@dataclasses.dataclass
class Record:
    features: Dict[str, float]
    payload: Dict[str, Any]


class VectorStore:
    def __init__(self):
        self._vecs: List[np.ndarray] = []
        self._records: List[Record] = []
        self._matrix: Optional[np.ndarray] = None

    def __len__(self) -> int:
        return len(self._records)

    def add(self, features: Dict[str, float], payload: Dict[str, Any]) -> None:
        self._vecs.append(embed_features(features))
        self._records.append(Record(features, payload))
        self._matrix = None  # invalidate

    def query(self, features: Dict[str, float],
              k: int = 8) -> List[Tuple[float, Record]]:
        if not self._records:
            return []
        if self._matrix is None:
            self._matrix = np.stack(self._vecs)
        q = embed_features(features)
        sims = self._matrix @ q
        k = min(k, len(sims))
        top = np.argpartition(-sims, k - 1)[:k]
        top = top[np.argsort(-sims[top])]
        return [(float(sims[i]), self._records[i]) for i in top]


class ContextQuantFeedbackDB(VectorStore):
    """context/preference features + bits -> realised satisfaction feedback."""

    def add_feedback(self, features: Dict[str, float], bits: int,
                     satisfaction: float, perf: Dict[str, float]) -> None:
        self.add(features, {"bits": bits, "satisfaction": satisfaction,
                            "perf": dict(perf)})

    def estimate_satisfaction(
        self, features: Dict[str, float], bits: int, k: int = 8
    ) -> Optional[Tuple[float, float]]:
        """(estimate, confidence) for assigning ``bits`` under ``features``.

        Retrieval is context-wide; matching-bit neighbours weigh fully,
        near-bit neighbours partially (quantization effects are smooth
        in log-bits).
        """
        hits = self.query(features, k=k * 4)
        if not hits:
            return None
        num = den = 0.0
        for sim, rec in hits:
            if sim <= 0:
                continue
            db = abs(np.log2(rec.payload["bits"]) - np.log2(bits))
            bit_w = max(0.0, 1.0 - 0.5 * db)
            w = sim * bit_w
            num += w * rec.payload["satisfaction"]
            den += w
        if den < 1e-6:
            return None
        conf = min(1.0, den / 3.0)
        return num / den, conf


class HardwareQuantPerfDB(VectorStore):
    """hardware features + bits -> measured perf dict."""

    def add_measurement(self, hw_features: Dict[str, float], bits: int,
                        perf: Dict[str, float]) -> None:
        self.add(hw_features, {"bits": bits, "perf": dict(perf)})

    def estimate_perf(
        self, hw_features: Dict[str, float], bits: int, k: int = 8
    ) -> Optional[Dict[str, float]]:
        hits = self.query(hw_features, k=k * 4)
        agg: Dict[str, float] = {}
        den = 0.0
        for sim, rec in hits:
            if sim <= 0 or rec.payload["bits"] != bits:
                continue
            for name, val in rec.payload["perf"].items():
                agg[name] = agg.get(name, 0.0) + sim * val
            den += sim
        if den < 1e-6:
            return None
        return {name: v / den for name, v in agg.items()}
