"""Simulated users: ground-truth preferences, contexts, and the
satisfaction oracle.

Every simulated user has *hidden* ground truth the planners never see:
sensitivity weights w_f over {accuracy, energy, latency} (Gaussian, per the
paper's §IV-A "Gaussian distributed sensitivity"), an operational context
(paper Table I factors), and a task-category mixture. Planners observe only
interview transcripts and RAG retrievals; the oracle scores what they chose.

Satisfaction oracle = the paper's Eq. (3) evaluated with the TRUE weights
and the TRUE context-modulated performance at the assigned precision.
"""

from __future__ import annotations

import dataclasses
import random
from typing import Dict, List

from repro.configs.base import BITS_TO_LEVEL
from repro.core.profiling.hardware import DeviceSpec

LOCATIONS = ["bedroom", "living_room", "kitchen", "office", "outdoor"]
# Table I: location -> input noise level (0 = quiet, 1 = very noisy)
LOCATION_NOISE = {
    "bedroom": 0.1,
    "living_room": 0.7,
    "kitchen": 0.6,
    "office": 0.3,
    "outdoor": 0.9,
}
TIMES = ["daytime", "nighttime"]
TIME_NOISE = {"daytime": 0.6, "nighttime": 0.2}
TIME_QUANTITY = {"daytime": 0.8, "nighttime": 0.3}
FREQUENCIES = ["low", "medium", "high"]
FREQ_QUANTITY = {"low": 0.2, "medium": 0.5, "high": 0.9}
CATEGORIES = ["entertainment", "smart_home", "general_query", "personal_request"]
# paper Table II global mixture
CATEGORY_PROBS = [0.327, 0.160, 0.319, 0.194]

FACTORS = ("accuracy", "energy", "latency")


@dataclasses.dataclass
class UserTruth:
    user_id: int
    weights: Dict[str, float]  # sensitivity w_f, sums to 1
    location: str
    interaction_time: str
    frequency: str
    category_mix: Dict[str, float]  # personal task-type distribution
    chattiness: float  # how much the user reveals in interviews (0..1)

    @property
    def noise_level(self) -> float:
        noise = 0.6 * LOCATION_NOISE[self.location]
        return min(1.0, noise + 0.4 * TIME_NOISE[self.interaction_time])

    @property
    def data_quantity(self) -> float:
        quantity = 0.5 * FREQ_QUANTITY[self.frequency]
        return quantity + 0.5 * TIME_QUANTITY[self.interaction_time]

    def context_features(self) -> Dict[str, float]:
        f = {
            "loc_" + self.location: 1.0,
            "time_" + self.interaction_time: 1.0,
            "freq_" + self.frequency: 1.0,
        }
        for c, p in self.category_mix.items():
            f["cat_" + c] = p
        return f


_WEIGHT_MEANS = {"accuracy": 1.25, "energy": 0.9, "latency": 0.85}


def _gaussian_weights(rng: random.Random) -> Dict[str, float]:
    """Gaussian-distributed sensitivities (paper §IV-A), clipped positive,
    normalised. Accuracy skews higher — voice assistants that mishear are
    the dominant complaint driver."""
    raw = {f: max(0.05, rng.gauss(_WEIGHT_MEANS[f], 0.45)) for f in FACTORS}
    s = sum(raw.values())
    return {f: v / s for f, v in raw.items()}


def make_users(n: int, seed: int = 0) -> List[UserTruth]:
    rng = random.Random(seed + 1)
    users = []
    for i in range(n):
        # per-user Dirichlet-ish category mixture centred on Table II
        alpha = [p * 6 for p in CATEGORY_PROBS]
        draws = [rng.gammavariate(a, 1.0) for a in alpha]
        tot = sum(draws)
        mix = {c: d / tot for c, d in zip(CATEGORIES, draws)}
        users.append(
            UserTruth(
                user_id=i,
                weights=_gaussian_weights(rng),
                location=rng.choices(LOCATIONS, [0.25, 0.3, 0.15, 0.2, 0.1])[0],
                interaction_time=rng.choices(TIMES, [0.65, 0.35])[0],
                frequency=rng.choices(FREQUENCIES, [0.3, 0.4, 0.3])[0],
                category_mix=mix,
                chattiness=rng.uniform(0.4, 1.0),
            )
        )
    return users


# ---------------------------------------------------------------------------
# performance model at precision level q (ground truth, context-modulated)
# ---------------------------------------------------------------------------


# Device-class deviations from the analytic priors — reality the planner
# can only learn through the Hardware-Quantization-Performance DB (a
# smart speaker's far-field mic array is noise-robust; an IoT hub's DSP
# handles low-bit inference poorly; flagship NPUs have fast int8 paths).
_CLASS_ACC_DEV = {
    "smart_speaker": {4: +0.06, 8: +0.04, 16: 0.0, 32: 0.0},
    "iot_hub": {4: -0.10, 8: -0.05, 16: 0.0, 32: 0.0},
    "flagship_phone": {4: +0.03, 8: +0.03, 16: 0.0, 32: 0.0},
}
_CLASS_LAT_DEV = {
    "flagship_phone": {4: -0.08, 8: -0.08, 16: -0.04, 32: 0.0},
    "iot_hub": {4: +0.05, 8: +0.05, 16: 0.0, 32: 0.0},
}


def true_performance(user: UserTruth, spec: DeviceSpec, bits: int) -> Dict[str, float]:
    """Realised (accuracy_utility, energy_cost, latency_cost), all in [0,1].

    Accuracy degrades faster at low precision in noisy contexts (quantized
    ASR is less robust to noise); energy/latency follow the analytic model
    scaled by device efficiency, plus device-class deviations the analytic
    priors do NOT capture (the HQP database's reason to exist).
    """
    lvl = BITS_TO_LEVEL[bits]
    noise = user.noise_level
    acc = lvl.rel_accuracy - lvl.noise_sensitivity * noise
    acc += _CLASS_ACC_DEV.get(spec.device_class, {}).get(bits, 0.0)
    acc = max(0.0, min(1.0, acc))
    # energy cost relative to running this device at 32-bit
    dev_scale = spec.energy_per_mac_pj / 3.0
    energy = min(1.0, lvl.rel_energy * (0.8 + 0.2 * dev_scale))
    # latency: slower devices feel quantization relief more
    speed = 250.0 / max(spec.cpu_gflops, 1.0)
    latency = lvl.rel_latency * (0.7 + 0.3 * min(speed, 2.0) / 2.0)
    latency += _CLASS_LAT_DEV.get(spec.device_class, {}).get(bits, 0.0)
    latency = max(0.0, min(1.0, latency))
    return {"accuracy": acc, "energy": energy, "latency": latency}


def eq3_score(
    weights: Dict[str, float],
    perf: Dict[str, float],
    *,
    contribution: float = 1.0,
    energy_priority: float = 1.0,
) -> float:
    """The paper's reward-penalty model, Eqs (1)-(3) — shared by the
    oracle (true weights, C_q=1) and the planner (estimates).

    Rewards R_f(q): accuracy utility, energy *saving* (1-E), latency
    *saving* (1-L) — the benefits of operating at level q.
    Penalties P_f(q): accuracy loss, energy cost (scaled by the server's
    energy-priority knob), latency cost.

        Score = C_q * sum_f w_f R_f  -  sum_f w_f P_f
    """
    w = weights
    acc, e, lat = perf["accuracy"], perf["energy"], perf["latency"]
    r_total = contribution * (
        w["accuracy"] * acc + w["energy"] * (1.0 - e) + w["latency"] * (1.0 - lat)
    )
    p_total = (
        w["accuracy"] * (1.0 - acc)
        + w["energy"] * e * energy_priority
        + w["latency"] * lat
    )
    return r_total - p_total


def satisfaction_score(user: UserTruth, spec: DeviceSpec, bits: int) -> float:
    """Oracle satisfaction: Eq. (3) with ground-truth weights and realised
    context-modulated performance (C_q = 1, no server priority)."""
    return eq3_score(user.weights, true_performance(user, spec, bits))


def best_possible_bits(user: UserTruth, spec: DeviceSpec) -> int:
    """Oracle-optimal precision (upper bound for planner evaluation)."""
    return max(spec.supported_bits, key=lambda b: satisfaction_score(user, spec, b))


# ---------------------------------------------------------------------------
# context drift (paper §III-A: "potential context change since the last
# feedback collection")
# ---------------------------------------------------------------------------


def drift_user(
    user: UserTruth,
    rng: random.Random,
    p_move: float = 0.08,
    p_schedule: float = 0.10,
) -> bool:
    """Mutate a user's operational context in place.

    Users occasionally relocate the device (bedroom -> kitchen changes the
    noise profile) or shift usage schedule (new job -> nighttime user).
    Returns True when anything changed — the FL server uses this to
    trigger a re-interview, exactly the paper's second interview trigger.
    """
    changed = False
    if rng.random() < p_move:
        user.location = rng.choice([l for l in LOCATIONS if l != user.location])
        changed = True
    if rng.random() < p_schedule:
        user.interaction_time = (
            "nighttime" if user.interaction_time == "daytime" else "daytime"
        )
        changed = True
    if rng.random() < 0.05:
        user.frequency = rng.choice([f for f in FREQUENCIES if f != user.frequency])
        changed = True
    return changed


def drift_device(spec: DeviceSpec, rng: random.Random) -> bool:
    """Power-state transitions (the paper's third trigger: changed
    hardware specifications -> prompt the user to update context)."""
    old = spec.power_state
    r = rng.random()
    if spec.power_state == "low_battery" and r < 0.5:
        spec.power_state = "charging"
    elif spec.power_state == "charging" and r < 0.6:
        spec.power_state = "normal"
    elif spec.power_state == "normal" and r < 0.1:
        spec.power_state = rng.choice(["low_battery", "charging"])
    return spec.power_state != old
