"""Context-Quantization Evaluation — the paper's reward-penalty model
(Eqs 1–4) plus the contribution multiplier C_q under the three server
strategies of §IV-B2.

    R_Total(q) = C_q * sum_f w_f R_f(q)          (1)
    P_Total(q) = sum_f w_f P_f(q)                 (2)
    Score(q)   = R_Total(q) - P_Total(q)          (3)
    q*         = argmax_q Score(q)                (4)

R_f / P_f come from RAG retrievals when the databases have relevant
history, falling back to the analytic precision priors
(``PrecisionLevel``) when they don't — "data-driven estimation" that
sharpens as feedback accumulates.

Retrieval is bits-agnostic, so each client needs exactly one hit list
per store per planning pass: ``evaluate_levels`` fetches them itself in
the per-client path, or scores the pre-fetched ``ctx_hits``/``hw_hits``
the cohort-batched planner hands in (one engine query for the whole
cohort, DESIGN.md §10).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.configs.base import BITS_TO_LEVEL
from repro.core.profiling.hardware import DeviceSpec
from repro.core.profiling.interview import InferredProfile
from repro.core.profiling.ragdb import (
    RETRIEVE_K,
    ContextQuantFeedbackDB,
    HardwareQuantPerfDB,
    Record,
    perf_from_hits,
    satisfaction_from_hits,
)
from repro.core.profiling.users import CATEGORIES, CATEGORY_PROBS, FACTORS, eq3_score

MINORITY = {"smart_home", "personal_request"}  # from Table II
MAJORITY = {"entertainment", "general_query"}

Hits = List[Tuple[float, Record]]


def prior_perf(bits: int) -> Dict[str, float]:
    lvl = BITS_TO_LEVEL[bits]
    return {
        "accuracy": lvl.rel_accuracy,
        "energy": lvl.rel_energy,
        "latency": lvl.rel_latency,
    }


def estimate_category_mix(profile: InferredProfile) -> Dict[str, float]:
    """Inferred data distribution from contextual signals (Table I:
    task type -> data distribution) blended with the global prior."""
    prior = dict(zip(CATEGORIES, CATEGORY_PROBS))
    sig = profile.category_signal
    if not sig:
        return prior
    tot_sig = sum(sig.values())
    mix = {}
    for c in CATEGORIES:
        s = sig.get(c, 0.0)
        mix[c] = 0.4 * prior[c] + 0.6 * (s / tot_sig if tot_sig else prior[c])
    tot = sum(mix.values())
    return {c: v / tot for c, v in mix.items()}


def contribution_multiplier(
    bits: int,
    profile: InferredProfile,
    strategy: str,
    max_bits: int = 32,
) -> float:
    """C_q: how much the server values this client training at ``bits``.

    Precision quality scales contribution (higher-precision updates carry
    more usable signal); the strategy reweights clients by their inferred
    class mixture:
      - fedavg: every sample equal -> quantity only.
      - class_equal: boost clients rich in minority classes.
      - majority_centric: boost clients rich in majority classes.
    """
    mix = estimate_category_mix(profile)
    quantity = 1.0
    if profile.frequency == "high":
        quantity = 1.3
    elif profile.frequency == "low":
        quantity = 0.75
    precision_quality = (bits / max_bits) ** 0.35
    if strategy == "fedavg":
        strat_w = 1.0
    elif strategy == "class_equal":
        minority_share = sum(mix[c] for c in MINORITY)
        strat_w = 0.45 + 2.2 * minority_share
    elif strategy == "majority_centric":
        majority_share = sum(mix[c] for c in MAJORITY)
        strat_w = 0.45 + 1.7 * majority_share
    else:
        raise ValueError(f"unknown strategy {strategy!r}")
    return quantity * strat_w * (0.6 + 0.8 * precision_quality)


@dataclasses.dataclass
class ScoredLevel:
    bits: int
    score: float
    reward: float
    penalty: float
    contribution: float
    source: str  # "rag" | "prior" | "blend"


def evaluate_levels(
    profile: InferredProfile,
    spec: DeviceSpec,
    cqf_db: ContextQuantFeedbackDB,
    hqp_db: HardwareQuantPerfDB,
    *,
    strategy: str = "fedavg",
    energy_priority: float = 1.0,
    ctx_hits: Optional[Hits] = None,
    hw_hits: Optional[Hits] = None,
) -> List[ScoredLevel]:
    """Score every hardware-feasible precision level via Eqs (1)–(3).

    ``energy_priority`` > 1 implements the paper's energy-savings mode
    (server scales the energy penalty for the whole federation).
    ``ctx_hits``/``hw_hits`` are optional pre-fetched retrievals (the
    cohort-batched path); absent, each store is queried once here — the
    hit lists are shared across precision levels either way.
    """
    w = profile.weights_estimate()
    if hw_hits is None:
        hw_hits = hqp_db.query(spec.features(), k=RETRIEVE_K)
    if ctx_hits is None:
        ctx_hits = cqf_db.query(profile.features(), k=RETRIEVE_K)
    out: List[ScoredLevel] = []
    for bits in spec.supported_bits:
        perf = perf_from_hits(hw_hits, bits)
        source = "rag"
        if perf is None:
            perf = prior_perf(bits)
            source = "prior"
        c_q = contribution_multiplier(bits, profile, strategy)
        # Eqs (1)-(3) via the shared reward-penalty scorer
        score = eq3_score(w, perf, contribution=c_q, energy_priority=energy_priority)
        rewards = (perf["accuracy"], 1 - perf["energy"], 1 - perf["latency"])
        reward = c_q * sum(w[f] * r for f, r in zip(FACTORS, rewards))
        penalty = reward - score
        # blend with retrieved direct satisfaction history when available
        est = satisfaction_from_hits(ctx_hits, bits)
        if est is not None:
            sat_est, conf = est
            # blend weight tuned on the ablation benchmark: 0.5*conf pulled
            # scores toward noisy neighbours and under-performed
            # interview-only profiling; 0.25*conf recovers the DB's value
            # as a correction rather than a replacement.
            score = (1 - 0.25 * conf) * score + 0.25 * conf * sat_est
            source = "blend"
        out.append(
            ScoredLevel(
                bits=bits,
                score=float(score),
                reward=float(reward),
                penalty=float(penalty),
                contribution=float(c_q),
                source=source,
            )
        )
    return out


def select_level(levels: Sequence[ScoredLevel]) -> ScoredLevel:
    return max(levels, key=lambda l: l.score)  # Eq (4)
