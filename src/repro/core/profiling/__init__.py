from repro.core.profiling.hardware import DeviceSpec, make_fleet, hardware_tier, max_feasible_bits  # noqa: F401
from repro.core.profiling.users import UserTruth, make_users, satisfaction_score, true_performance  # noqa: F401
from repro.core.profiling.interview import InterviewAgent, SimLLM, InferredProfile  # noqa: F401
from repro.core.profiling.ragdb import ContextQuantFeedbackDB, HardwareQuantPerfDB, VectorStore  # noqa: F401
from repro.core.profiling.evaluator import evaluate_levels, select_level, contribution_multiplier  # noqa: F401
from repro.core.profiling.planner import RAGPlanner, UnifiedTierPlanner, PlanDecision, plan_round  # noqa: F401
