from repro.core.profiling.evaluator import (
    contribution_multiplier,
    evaluate_levels,
    select_level,
)
from repro.core.profiling.hardware import (
    DeviceSpec,
    hardware_tier,
    make_fleet,
    max_feasible_bits,
)
from repro.core.profiling.interview import InferredProfile, InterviewAgent, SimLLM
from repro.core.profiling.planner import (
    PlanDecision,
    RAGPlanner,
    UnifiedTierPlanner,
    plan_round,
)
from repro.core.profiling.ragdb import (
    ContextQuantFeedbackDB,
    HardwareQuantPerfDB,
    VectorStore,
    embed_batch,
    embed_features,
)
from repro.core.profiling.users import (
    UserTruth,
    make_users,
    satisfaction_score,
    true_performance,
)
