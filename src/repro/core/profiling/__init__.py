from repro.core.profiling.hardware import (DeviceSpec,  # noqa: F401
                                           hardware_tier, make_fleet,
                                           max_feasible_bits)
from repro.core.profiling.users import (UserTruth,  # noqa: F401
                                        make_users, satisfaction_score,
                                        true_performance)
from repro.core.profiling.interview import (InferredProfile,  # noqa: F401
                                            InterviewAgent, SimLLM)
from repro.core.profiling.ragdb import (ContextQuantFeedbackDB,  # noqa: F401
                                        HardwareQuantPerfDB, VectorStore)
from repro.core.profiling.evaluator import (contribution_multiplier,  # noqa: F401
                                            evaluate_levels, select_level)
from repro.core.profiling.planner import (PlanDecision,  # noqa: F401
                                          RAGPlanner, UnifiedTierPlanner,
                                          plan_round)
