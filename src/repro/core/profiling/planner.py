"""Precision planners: the paper's RAG planner, the unified-tier baseline,
and the server-side multi-client quantization planning step.

``RAGPlanner`` runs the 6-step user-profiling pipeline (paper §III-B3):
  1. hardware specification extraction
  2. hardware-quantization-performance trade-off retrieval
  3. user interview feedback collection
  4. contextual factor inference
  5. user preference / contextual factor retrieval
  6. satisfaction + contribution estimation  ->  Eqs (1)-(4)

Two entry points share the pipeline: ``plan`` runs it per client (the
readable specification), ``plan_cohort`` batches step (2) and (5) across
the whole cohort — embed every client's context and hardware features
once, then issue ONE batched engine query per store per round instead of
a numpy scan per client (DESIGN.md §10). The FL server's round loop uses
``plan_cohort``.

``UnifiedTierPlanner`` is the paper's §IV comparison: tier clients by
hardware capability alone; every tier member gets the same bits.

``plan_round`` implements the FL server's "multi-client quantization
planning": clients whose top levels have similar merit get nudged into
the precision slots that maximise mixed-precision OTA utilization.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

from repro.core.profiling.evaluator import ScoredLevel, evaluate_levels, select_level
from repro.core.profiling.hardware import (
    TIER_BITS,
    DeviceSpec,
    hardware_tier,
    max_feasible_bits,
)
from repro.core.profiling.interview import InferredProfile, InterviewAgent
from repro.core.profiling.ragdb import (
    RETRIEVE_K,
    ContextQuantFeedbackDB,
    HardwareQuantPerfDB,
    embed_batch,
)
from repro.core.profiling.users import UserTruth


@dataclasses.dataclass
class PlanDecision:
    user_id: int
    bits: int
    score_est: float
    levels: List[ScoredLevel]
    transcript: str = ""


class BasePlanner:
    name = "base"

    def plan(self, users, specs, **kw) -> List[PlanDecision]:
        raise NotImplementedError

    def plan_cohort(self, users, specs, **kw) -> List[PlanDecision]:
        """Batched planning pass; planners without a batched retrieval
        path fall back to the per-client pipeline."""
        return self.plan(users, specs, **kw)

    def observe_feedback(self, *a, **kw) -> None:
        pass


class UnifiedTierPlanner(BasePlanner):
    """Hardware tiers only — ignores preferences and contexts (paper §IV)."""

    name = "unified"

    def plan(
        self, users: Sequence[UserTruth], specs: Sequence[DeviceSpec], **kw
    ) -> List[PlanDecision]:
        out = []
        for u, s in zip(users, specs):
            bits = min(TIER_BITS[hardware_tier(s)], max_feasible_bits(s))
            # clamp to a supported level
            feasible = [b for b in s.supported_bits if b <= bits]
            bits = max(feasible) if feasible else min(s.supported_bits)
            out.append(PlanDecision(u.user_id, bits, 0.0, []))
        return out


class RAGPlanner(BasePlanner):
    """The paper's planner: interview -> infer -> retrieve -> Eqs (1)-(4)."""

    name = "rag"

    def __init__(
        self,
        *,
        strategy: str = "fedavg",
        energy_priority: float = 1.0,
        seed: int = 0,
    ):
        self.agent = InterviewAgent(seed=seed)
        self.cqf_db = ContextQuantFeedbackDB()
        self.hqp_db = HardwareQuantPerfDB()
        self.strategy = strategy
        self.energy_priority = energy_priority
        self.profiles: Dict[int, InferredProfile] = {}

    def _interview(self, user: UserTruth) -> Tuple[str, InferredProfile]:
        """(3) interview + (4) contextual factor inference — refreshed
        each planning pass; repeated interviews accumulate by field-wise
        max-confidence merge."""
        transcript, prof = self.agent.interview(user)
        prev = self.profiles.get(user.user_id)
        if prev is not None:
            prof = _merge_profiles(prev, prof)
        self.profiles[user.user_id] = prof
        return transcript, prof

    def plan(
        self, users: Sequence[UserTruth], specs: Sequence[DeviceSpec], **kw
    ) -> List[PlanDecision]:
        out = []
        for u, s in zip(users, specs):
            transcript, prof = self._interview(u)
            # (1)(2)(5)(6): hardware extraction + retrievals + Eqs (1)-(4)
            levels = evaluate_levels(
                prof,
                s,
                self.cqf_db,
                self.hqp_db,
                strategy=self.strategy,
                energy_priority=self.energy_priority,
            )
            best = select_level(levels)
            out.append(
                PlanDecision(u.user_id, best.bits, best.score, levels, transcript)
            )
        return out

    def plan_cohort(
        self, users: Sequence[UserTruth], specs: Sequence[DeviceSpec], **kw
    ) -> List[PlanDecision]:
        """The batched pipeline: same decisions as ``plan``, one engine
        query per store for the whole cohort instead of 2K serial scans.

        Steps (3)-(4) stay per client (interviews are conversations);
        steps (2) and (5) embed all K feature dicts once and retrieve in
        one (K, D) batch per store; step (6) scores the pre-fetched hit
        lists per client.
        """
        if type(self).plan is not RAGPlanner.plan:
            # a subclass customized the per-client pipeline (e.g. the
            # ablation planners) — honor it rather than silently running
            # the base pipeline through the batched path
            return self.plan(users, specs, **kw)
        if not users or not specs:
            return []
        interviews = [self._interview(u) for u in users]
        profs = [prof for _, prof in interviews]
        ctx_q = embed_batch([p.features() for p in profs])
        hw_q = embed_batch([s.features() for s in specs])
        ctx_hits = self.cqf_db.query_batch(ctx_q, k=RETRIEVE_K)
        hw_hits = self.hqp_db.query_batch(hw_q, k=RETRIEVE_K)
        out = []
        for i, (u, s) in enumerate(zip(users, specs)):
            levels = evaluate_levels(
                profs[i],
                s,
                self.cqf_db,
                self.hqp_db,
                strategy=self.strategy,
                energy_priority=self.energy_priority,
                ctx_hits=ctx_hits[i],
                hw_hits=hw_hits[i],
            )
            best = select_level(levels)
            transcript = interviews[i][0]
            out.append(
                PlanDecision(u.user_id, best.bits, best.score, levels, transcript)
            )
        return out

    def observe_feedback(
        self,
        user: UserTruth,
        spec: DeviceSpec,
        bits: int,
        satisfaction: float,
        perf: Dict[str, float],
    ) -> None:
        """Close the loop: archive realised outcomes into both DBs."""
        prof = self.profiles.get(user.user_id)
        feats = prof.features() if prof else {}
        self.cqf_db.add_feedback(feats, bits, satisfaction, perf)
        self.hqp_db.add_measurement(spec.features(), bits, perf)


def _merge_profiles(old: InferredProfile, new: InferredProfile) -> InferredProfile:
    merged = InferredProfile(user_id=new.user_id)
    fields = (
        ("location", "location_conf"),
        ("time", "time_conf"),
        ("frequency", "frequency_conf"),
    )
    for field, conf_field in fields:
        o_v, o_c = getattr(old, field), getattr(old, conf_field)
        n_v, n_c = getattr(new, field), getattr(new, conf_field)
        if n_c >= o_c:
            setattr(merged, field, n_v)
            setattr(merged, conf_field, n_c)
        else:
            setattr(merged, field, o_v)
            setattr(merged, conf_field, o_c)
    for f in old.sens:
        merged.sens[f] = 0.6 * old.sens[f] + 0.6 * new.sens[f]
    cats = set(old.category_signal) | set(new.category_signal)
    merged.category_signal = {
        c: max(old.category_signal.get(c, 0.0), new.category_signal.get(c, 0.0))
        for c in cats
    }
    return merged


# ---------------------------------------------------------------------------
# multi-client quantization planning (FL server, paper §III-A)
# ---------------------------------------------------------------------------


def plan_round(
    decisions: List[PlanDecision],
    *,
    merit_epsilon: float = 0.04,
    slot_bits: Sequence[int] = (4, 8, 16, 32),
) -> List[PlanDecision]:
    """Pack near-tied clients into fewer precision slots.

    Mixed-precision OTA aggregation is most spectrally efficient when the
    active precision set is small (fewer constellation alignments). For
    each client whose runner-up level scores within ``merit_epsilon`` of
    its best, prefer the level that is already most popular this round.
    """
    counts: Dict[int, int] = {b: 0 for b in slot_bits}
    for d in decisions:
        counts[d.bits] = counts.get(d.bits, 0) + 1
    out = []
    for d in decisions:
        if d.levels:
            near = [l for l in d.levels if d.score_est - l.score <= merit_epsilon]
            if len(near) > 1:
                best = max(near, key=lambda l: (counts.get(l.bits, 0), l.score))
                if best.bits != d.bits:
                    counts[d.bits] -= 1
                    counts[best.bits] = counts.get(best.bits, 0) + 1
                    d = dataclasses.replace(d, bits=best.bits, score_est=best.score)
        out.append(d)
    return out
