"""Physical OTA channel model: block fading, truncated channel inversion
power control, and misalignment (DESIGN.md §12).

The aggregation data plane (``core/ota.py``) historically modelled the
"Over-the-Air" half of the system as one receiver AWGN term plus a
participation coin-flip. This module adds the physical layer the source
model (paper refs; "Over-the-Air Federated Learning from Heterogeneous
Data", arXiv 2009.12787) actually derives:

- **Block fading.** Per client k and round, a complex channel
  coefficient h_k ~ CN(0, beta_k) — Rayleigh magnitude |h_k| with an
  optional per-client log-normal shadowing/path-loss spread beta_k
  (``pathloss_spread_db``). The draw comes from a *dedicated* stream
  derived off the round key (``derive_channel_key``), disjoint from the
  legacy channel/dither/noise splits, so enabling the model never
  perturbs the AWGN or stochastic-rounding draws.
- **Truncated channel inversion.** A client in a deep fade cannot
  invert its channel within any finite power budget; clients with
  |h_k|^2 < ``fade_threshold`` transmit at zero power and are excluded
  from the round (and from the FedAvg weight renormalisation — see
  ``combine_weights``). Survivors pre-scale their analog symbols by
  rho / |h_k| (phase-corrected), so their signals superpose aligned at
  the receiver.
- **Power budget + misalignment.** The inversion amplitude is capped at
  sqrt(``power_budget``): a surviving client whose channel is weak
  transmits at the cap and arrives *mis-aligned*, with effective
  receive gain g_k = |h_k| * a_k / rho = min(1, |h_k| sqrt(P) / rho)
  < 1. The per-row gain vector g is what the fused aggregation pass
  consumes (``kernels/ota_fused.ota_packed_2d`` with ``gains=``;
  DESIGN.md §12) — g_k = 0 encodes truncation, g_k = 1 perfect
  inversion, and 1 - g_k is the residual misalignment error.

Everything is a pure function of (round key, config): the barrier and
streaming round loops sample the same ``ChannelState`` for the same
round, and a seeded run replays bit-for-bit.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp

# Stream tag for the channel fading draw. The legacy round draws are the
# three ``jax.random.split(key, 3)`` children (channel coin-flip, SR
# dither seed, AWGN); ``fold_in`` with this constant derives a fourth,
# provably distinct stream (tests/test_channel.py pins the separation).
_CHANNEL_STREAM = 0x0C4A17
_TINY = 1e-12


def derive_channel_key(key) -> jax.Array:
    """The round's dedicated fading-draw key.

    ``jax.random.fold_in`` of the round key with the channel stream tag:
    disjoint by construction from the ``split(key, 3)`` children that
    feed the legacy participation draw, the stochastic-rounding dither
    seed (``ota.derive_sr_seed``), and the receiver AWGN — adding the
    physical channel cannot collide with (or shift) any legacy stream.
    """
    return jax.random.fold_in(key, _CHANNEL_STREAM)


@dataclasses.dataclass(frozen=True)
class ChannelConfig:
    """Physical-channel knobs (hashable: usable as a jit static arg).

    fade_threshold: truncation threshold on the channel power |h_k|^2 —
    below it the client transmits at zero power this round.
    rho: target alignment amplitude at the receiver (the common analog
    scale every surviving client inverts toward).
    power_budget: per-client maximum transmit *power* P; the inversion
    amplitude rho / |h_k| is capped at sqrt(P).
    pathloss_spread_db: std (dB) of a per-client log-normal
    shadowing/path-loss term multiplying the Rayleigh channel power;
    0 disables it (i.i.d. unit-power Rayleigh).
    """

    fade_threshold: float = 0.1
    rho: float = 1.0
    power_budget: float = 100.0
    pathloss_spread_db: float = 0.0


@dataclasses.dataclass(frozen=True)
class ChannelState:
    """One round's realised channel over a K-client cohort.

    habs: (K,) fading magnitudes |h_k| (Rayleigh x shadowing).
    gains: (K,) effective receive gain g_k in [0, 1] — 0 for truncated
    clients, 1 under perfect inversion, in between when the power
    budget binds. This is the per-row vector the fused pass consumes.
    tx_amp: (K,) transmit amplitude a_k actually used (0 when
    truncated; a_k^2 <= power_budget always).
    """

    habs: jnp.ndarray
    gains: jnp.ndarray
    tx_amp: jnp.ndarray

    @property
    def truncated(self) -> jnp.ndarray:
        """(K,) bool: clients excluded by truncated channel inversion."""
        return self.gains <= 0

    @property
    def n_truncated(self) -> int:
        return int(jax.device_get(self.truncated).sum())

    @property
    def misalignment(self) -> jnp.ndarray:
        """(K,) residual alignment error 1 - g_k over surviving clients
        (0 for truncated clients — they contribute nothing, aligned or
        not)."""
        return jnp.where(self.truncated, 0.0, 1.0 - self.gains)

    def snr_db(self, snr_db: float) -> jnp.ndarray:
        """(K,) per-client effective receive SNR (dB): the configured
        receiver SNR shifted by the realised channel power |h_k|^2 —
        the profiling feature the planner sees (DESIGN.md §12)."""
        h2 = jnp.maximum(self.habs**2, _TINY)
        return jnp.float32(snr_db) + 10.0 * jnp.log10(h2)


# Pytree registration: jitted samplers return a ChannelState directly.
jax.tree_util.register_dataclass(
    ChannelState, data_fields=["habs", "gains", "tx_amp"], meta_fields=[]
)


@functools.partial(jax.jit, static_argnames=("n_clients", "cfg"))
def _sample_habs(key, *, n_clients: int, cfg: ChannelConfig) -> jnp.ndarray:
    """Rayleigh |h| with optional log-normal shadowing, from the
    dedicated channel stream of ``key``."""
    kr, ki, ks = jax.random.split(derive_channel_key(key), 3)
    hr = jax.random.normal(kr, (n_clients,)) * jnp.sqrt(0.5)
    hi = jax.random.normal(ki, (n_clients,)) * jnp.sqrt(0.5)
    h2 = hr**2 + hi**2
    if cfg.pathloss_spread_db > 0.0:
        shadow_db = jax.random.normal(ks, (n_clients,)) * cfg.pathloss_spread_db
        h2 = h2 * 10.0 ** (shadow_db / 10.0)
    return jnp.sqrt(h2)


@functools.partial(jax.jit, static_argnames=("cfg",))
def state_from_habs(habs: jnp.ndarray, *, cfg: ChannelConfig) -> ChannelState:
    """Truncated channel inversion of realised magnitudes ``habs``.

    Pure and draw-free — the deterministic half of ``ChannelModel.
    sample``, exposed so tests can pin exact boundary cases (|h|^2 ==
    threshold, budget exactly at the inversion point). Truncation uses
    ``>=``: a client exactly at the threshold participates.
    """
    habs = jnp.asarray(habs, jnp.float32)
    participate = habs**2 >= cfg.fade_threshold
    inv = cfg.rho / jnp.maximum(habs, _TINY)
    tx_amp = jnp.where(
        participate, jnp.minimum(inv, jnp.sqrt(cfg.power_budget)), 0.0
    )
    gains = habs * tx_amp / cfg.rho
    return ChannelState(habs=habs, gains=gains, tx_amp=tx_amp)


@jax.jit
def combine_weights(weights, gains) -> jnp.ndarray:
    """FedAvg weight renormalisation over the *surviving* clients.

    Truncated clients (g_k = 0) are excluded from the normaliser — the
    round's aggregate is the weighted mean of the clients that actually
    transmit, exactly as the legacy path excludes its coin-flip
    non-participants (``ota.round_channel``; same 1e-12 guard, so an
    all-truncated round yields all-zero weights, not NaN).
    """
    w = jnp.asarray(weights, jnp.float32) * (jnp.asarray(gains) > 0)
    return w / jnp.maximum(jnp.sum(w), _TINY)


class ChannelModel:
    """Seeded per-round physical channel (module docstring; DESIGN.md §12).

    Stateless between rounds: ``sample(round_key, K)`` is a pure
    function, so the barrier server (sampling before local training to
    plan around truncated clients) and the streaming server (folding
    gains at trigger time) see the identical ``ChannelState`` for the
    same round key.
    """

    def __init__(self, cfg: ChannelConfig = ChannelConfig()):
        self.cfg = cfg

    def sample(self, round_key, n_clients: int) -> ChannelState:
        """Draw one round's fading + run truncated inversion."""
        habs = _sample_habs(round_key, n_clients=n_clients, cfg=self.cfg)
        return state_from_habs(habs, cfg=self.cfg)

    def combine_weights(self, weights, state: ChannelState) -> jnp.ndarray:
        """Survivor-renormalised combining weights for ``state``."""
        return combine_weights(weights, state.gains)

    def uncontrolled_gains(self, state: ChannelState) -> jnp.ndarray:
        """Counterfactual receive gains with NO power control: every
        client transmits at the full budget amplitude, so row k arrives
        with gain |h_k| sqrt(P) / rho — the heterogeneous-magnitude
        baseline the inversion exists to flatten (bench_channel.py
        measures the variance shrink)."""
        amp = jnp.sqrt(jnp.float32(self.cfg.power_budget))
        return state.habs * amp / self.cfg.rho


def split_survivors(
    state: ChannelState,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(surviving row indices, truncated row indices) as int32 arrays —
    the server-side scheduling view of a sampled state."""
    trunc = jax.device_get(state.truncated)
    keep = jnp.asarray([i for i, t in enumerate(trunc) if not t], jnp.int32)
    drop = jnp.asarray([i for i, t in enumerate(trunc) if t], jnp.int32)
    return keep, drop
