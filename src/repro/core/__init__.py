from repro.core import channel, ota, quant  # noqa: F401
