from repro.core import channel, ota, quant, wire  # noqa: F401
