from repro.core import ota, quant  # noqa: F401
