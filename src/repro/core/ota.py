"""Mixed-precision Over-the-Air aggregation (the MP-OTA-FL data plane).

Physical model (paper refs [1], [2]):

- Block-fading Rayleigh channel per client per round: h_i ~ CN(0, 1).
- Truncated channel inversion power control: clients with |h_i|^2 below a
  threshold are excluded for the round (deep fade); the rest pre-scale by
  alpha_i / h_i so their analog signals superpose to the FedAvg-weighted sum.
- Mixed-precision modulation: each client transmits its *quantized* update
  on a shared symmetric analog grid; a client at b bits occupies every
  2^(B_max - b)-th constellation point, so coarser clients ride the same
  OTA symbols at no extra channel uses — this is how the scheme "covers the
  quantization overheads".
- The server receives  sum_i alpha_i * dq(update_i)  + AWGN scaled by the
  receive SNR and the number of participating clients' aligned power.

TPU mapping (DESIGN.md §4): superposition is a reduction. In the
distributed runtime the per-client updates live sharded across the mesh's
``data`` axis and the superposition lowers to a ``psum``/reduce-scatter;
in the single-host FL simulator it is the stacked-sum below. The noise is
injected *pre-reduction*, exactly where the channel adds it.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import quant

Pytree = Any


@dataclasses.dataclass(frozen=True)
class OTAConfig:
    snr_db: float = 20.0
    fade_threshold: float = 0.1  # |h|^2 truncation threshold
    max_bits: int = 32


def sample_channel(key, n_clients: int,
                   fade_threshold: float = 0.1) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Rayleigh fading gains. Returns (|h| (n,), participation mask (n,))."""
    kr, ki = jax.random.split(key)
    hr = jax.random.normal(kr, (n_clients,)) * jnp.sqrt(0.5)
    hi = jax.random.normal(ki, (n_clients,)) * jnp.sqrt(0.5)
    h2 = hr ** 2 + hi ** 2
    return jnp.sqrt(h2), h2 >= fade_threshold


def ota_aggregate(
    key,
    updates: Sequence[Pytree],
    bits: Sequence[int],
    weights: Sequence[float],
    cfg: OTAConfig = OTAConfig(),
) -> Tuple[Pytree, Dict[str, Any]]:
    """Aggregate client updates over the simulated OTA channel.

    updates: per-client pytrees (same structure). bits: per-client precision.
    weights: FedAvg weights (sum need not be 1; renormalised over the
    participating set after fade truncation).

    Returns (aggregated update, info dict with participation/noise stats).
    """
    n = len(updates)
    k_chan, k_quant, k_noise = jax.random.split(key, 3)
    habs, participate = sample_channel(k_chan, n, cfg.fade_threshold)
    participate_list = [bool(participate[i]) for i in range(n)]

    w = jnp.asarray(weights, jnp.float32) * participate
    w_sum = jnp.maximum(jnp.sum(w), 1e-12)
    w = w / w_sum

    # client-side: quantize at the planned precision (stochastic rounding —
    # unbiased so the OTA expectation is exact), then dequantise onto the
    # shared analog grid.
    qkeys = jax.random.split(k_quant, n)
    leaves0, treedef = jax.tree.flatten(updates[0])
    agg_leaves = [jnp.zeros_like(l, jnp.float32) for l in leaves0]
    for i in range(n):
        q_tree, s_tree = quant.quantize_tree(updates[i], int(bits[i]), key=qkeys[i])
        dq = quant.dequantize_tree(q_tree, s_tree, int(bits[i]))
        dq_leaves = jax.tree.leaves(dq)
        wi = w[i]
        agg_leaves = [a + wi * l for a, l in zip(agg_leaves, dq_leaves)]

    # receiver AWGN: noise std chosen so that per-element
    # SNR = ||aggregate|| / ||noise|| matches cfg.snr_db.
    total_elems = sum(l.size for l in agg_leaves)
    agg_norm2 = sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in agg_leaves)
    noise_power = agg_norm2 / total_elems * 10 ** (-cfg.snr_db / 10)
    noise_std = jnp.sqrt(noise_power)
    nkeys = jax.random.split(k_noise, len(agg_leaves))
    noisy = [
        a + noise_std * jax.random.normal(nk, a.shape)
        for a, nk in zip(agg_leaves, nkeys)
    ]
    info = {
        "participation": participate_list,
        "n_participating": int(jnp.sum(participate)),
        "noise_std": float(noise_std),
        "channel_abs": [float(habs[i]) for i in range(n)],
    }
    return jax.tree.unflatten(treedef, noisy), info


def channel_uses(bits: Sequence[int], n_params: int, cfg: OTAConfig = OTAConfig()) -> int:
    """OTA channel uses for one aggregation round.

    Mixed-precision modulation shares symbols across precisions: the round
    costs n_params symbols at the *max* participating precision's
    constellation — clients at lower b simply use coarser points. (This is
    the "quantization overhead covered by OTA" property: cost does NOT sum
    over clients.)
    """
    return n_params


def digital_uplink_bits(bits: Sequence[int], n_params: int) -> int:
    """Baseline comparison: digital per-client uplink cost (sums over clients)."""
    return int(sum(int(b) * n_params for b in bits))
