"""Mixed-precision Over-the-Air aggregation (the MP-OTA-FL data plane).

Physical model (paper refs [1], [2]):

- Block-fading Rayleigh channel per client per round: h_i ~ CN(0, 1).
- Truncated channel inversion power control: clients with |h_i|^2 below a
  threshold are excluded for the round (deep fade); the rest pre-scale by
  alpha_i / h_i so their analog signals superpose to the FedAvg-weighted sum.
- Mixed-precision modulation: each client transmits its *quantized* update
  on a shared symmetric analog grid; a client at b bits occupies every
  2^(B_max - b)-th constellation point, so coarser clients ride the same
  OTA symbols at no extra channel uses — this is how the scheme "covers the
  quantization overheads".
- The server receives  sum_i alpha_i * dq(update_i)  + AWGN scaled by the
  receive SNR and the number of participating clients' aligned power.

Data plane (flat pipeline, DESIGN.md §5)
----------------------------------------

The per-round hot path is one flat, batched, jitted program:

1. **Pack** every client's update pytree into a padded flat f32 row via
   ``core.packing`` (the FL server derives the layout once at init and
   passes it down; the pytree entry point below derives it per call),
   giving the ``(K, M)`` client-update matrix — the OTA superposition is a
   reduction over its K axis, so cohort size never changes program shape
   beyond K. In the end-to-end FL loop the row additionally goes out as a
   *quantized, bit-packed* wire row (``quantize_uplink`` ->
   ``packing.PackedRow``, DESIGN.md §6): 4-bit clients ship two symbols
   per byte, so the simulator's uplink traffic matches the air interface
   instead of being 8x f32-inflated.
2. **Fuse** the per-round quantize/superpose into ONE pass over
   (K, block) tiles (``kernels/ota_fused.py`` on TPU; jnp oracles in
   ``kernels/ref.py`` on CPU, where interpret-mode Pallas is a
   correctness tool, not a perf path). Two in-pass variants share the
   dither stream and grid semantics: f32 rows run stochastic quantize ->
   dequantize -> weighted superposition (``ota_fused_2d``); packed rows
   arrive pre-quantized and run unpack -> dequant -> superposition per
   storage class (``ota_packed_2d``). The in-pass (f32) quantizer uses
   a single per-update quant scale — one analog constellation per
   client per round, the faithful physical choice. Packed wire rows may
   additionally carry *blockwise* scales (``quantize_uplink`` with
   ``block`` > 0, DESIGN.md §6): one f32 per ``block`` symbols, indexed
   in-pass via a (K, n_blocks) scale matrix, so heterogeneous-magnitude
   updates don't let one outlier leaf inflate the whole row's int grid.
   The kernel is bits-agnostic (precision enters as (K,) or
   (K, n_blocks) scale arrays plus (K,) qmax), so one compiled program
   serves every precision mix and the jit cache keys only on
   (K, M, n_blocks).
3. **AWGN epilogue**: the noise std is calibrated to the *global*
   aggregate norm (receive SNR), which only exists after the reduction,
   so the O(M) noise axpy rides the same jitted program right after the
   single O(K*M) pass (the kernel emits the running squared norm).
4. **Unpack** the aggregate back to the update pytree (kept f32 for the
   server optimizer).

``ota_aggregate_pertree`` keeps the legacy per-client/per-leaf Python
loop with identical semantics and PRNG stream — it is the reference
oracle the flat path is equivalence-tested against (tests/test_ota.py),
not a production path.

TPU mapping (DESIGN.md §4): superposition is a reduction. In the
distributed runtime the per-client updates live sharded across the mesh's
``data`` axis and the superposition lowers to a ``psum``/reduce-scatter;
in the single-host FL simulator it is the fused kernel above. The noise
is injected post-reduction at the calibrated receive SNR, exactly where
the channel adds it.
"""

from __future__ import annotations

import dataclasses
import functools
import math
import os
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from collections.abc import Mapping

from repro import obs
from repro.core import channel as chan
from repro.core import packing, quant, wire
from repro.kernels import ops as kops
from repro.kernels import ref as kref

Pytree = Any


@dataclasses.dataclass(frozen=True)
class OTAConfig:
    snr_db: float = 20.0
    fade_threshold: float = 0.1  # |h|^2 truncation threshold
    max_bits: int = 32


def sample_channel(
    key, n_clients: int, fade_threshold: float = 0.1
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Rayleigh fading gains. Returns (|h| (n,), participation mask (n,))."""
    kr, ki = jax.random.split(key)
    hr = jax.random.normal(kr, (n_clients,)) * jnp.sqrt(0.5)
    hi = jax.random.normal(ki, (n_clients,)) * jnp.sqrt(0.5)
    h2 = hr**2 + hi**2
    return jnp.sqrt(h2), h2 >= fade_threshold


def _use_kernel_default() -> bool:
    """Pallas kernel on TPU; fused jnp reference everywhere else.

    TPU only, not any accelerator: the kernel's sequential-grid
    sum-of-squares accumulation is a TPU pattern (GPU grids run blocks in
    parallel). On CPU, interpret-mode Pallas runs the kernel body per grid
    step under the interpreter — orders of magnitude slower than the
    XLA-fused jnp formulation with identical numerics.
    REPRO_OTA_FORCE_KERNEL=1 forces the kernel anyway (interpret mode on
    CPU), e.g. for equivalence testing.
    """
    forced = os.environ.get("REPRO_OTA_FORCE_KERNEL")
    if forced is not None:
        return forced.strip().lower() not in ("0", "false", "no", "off", "")
    return jax.devices()[0].platform == "tpu"


def _client_grid(bits: jnp.ndarray, amax: jnp.ndarray):
    """Per-client analog grid: (scale, qmax) arrays from (bits, amax).

    qmax == 0 marks an unquantized (bits >= 32) client; its scale is 1 and
    the data plane passes its symbols through untouched.
    """
    bits = jnp.asarray(bits, jnp.int32)
    qmax = jnp.where(bits < 32, jnp.exp2((bits - 1).astype(jnp.float32)) - 1.0, 0.0)
    scale = jnp.where(qmax > 0, jnp.maximum(amax, 1e-12) / jnp.maximum(qmax, 1.0), 1.0)
    return scale, qmax


def derive_sr_seed(key) -> jnp.ndarray:
    """The round's stochastic-rounding seed, as ``ota_aggregate_flat``
    derives it internally from the round key.

    Clients quantizing at the edge (``quantize_uplink``) need this seed
    *before* the aggregation call; deriving it from the same key split
    keeps the packed path bit-identical to in-aggregate quantization (and
    to ``ota_aggregate_pertree``) for the same round key.
    """
    _, k_quant, _ = jax.random.split(key, 3)
    return jax.random.bits(k_quant, (), jnp.uint32)


def derive_dl_seed(key) -> jnp.ndarray:
    """The round's *downlink* dither seed (DESIGN.md §13).

    The server stochastic-quantizes the global param delta exactly once
    per round with this seed (``wire.encode_row`` at row 0) before
    broadcasting; decoding is deterministic, so every client reconstructs
    bit-identical params from the one encoded row. Derived from the same
    quantization key split as ``derive_sr_seed`` but folded with a
    downlink tag, so the two legs' dither streams are disjoint — a
    client's uplink symbols and the broadcast it just received never
    share rounding draws.
    """
    _, k_quant, _ = jax.random.split(key, 3)
    return jax.random.bits(jax.random.fold_in(k_quant, 0xD0_4B17), (), jnp.uint32)


def quantize_uplink(
    row: jnp.ndarray,
    bits: int,
    sr_seed: jnp.ndarray,
    row_index: int,
    *,
    block: int = 0,
) -> packing.PackedRow:
    """Modulate one client's flat packed row onto the wire (DESIGN.md §6).

    Thin alias for ``wire.encode_row`` — the symmetric codec facade both
    legs share (DESIGN.md §13) — kept for the established uplink call
    sites and tests. ``row_index`` = the client's row in this round's
    cohort (reporting clients only), dithering off ``derive_sr_seed``'s
    stream; ``block`` > 0 ships blockwise scales (``packing.QUANT_BLOCK``
    is the FL default). The server dequantizes inside the fused
    aggregation pass — the f32 row never crosses the uplink.
    """
    return wire.encode_row(row, bits, sr_seed, row_index, block=block)


def dequantize_uplink(row: packing.PackedRow, n: Optional[int] = None) -> jnp.ndarray:
    """Reconstruct the f32 row a ``PackedRow`` encodes (q * scale[block]).

    Thin alias for ``wire.decode_row``. The uplink data plane never does
    this on the host — dequantization lives inside the fused pass — but
    the quantization-*error* measurements
    (``benchmarks/bench_aggregation.py``) and the blockwise edge tests
    need the reconstruction standalone. ``n`` trims to the logical
    (unpadded) length.
    """
    return wire.decode_row(row, n)


@functools.partial(jax.jit, static_argnames=("cfg", "n_valid", "use_kernel"))
def ota_aggregate_flat(
    key,
    X: jnp.ndarray,
    bits: jnp.ndarray,
    weights: jnp.ndarray,
    *,
    cfg: OTAConfig,
    n_valid: int,
    use_kernel: bool = False,
):
    """One-shot OTA aggregation of the flat (K, M) client-update matrix.

    X rows are zero-padded packed updates (``core.packing``); ``n_valid``
    is the real (unpadded) parameter count. bits/weights are (K,) arrays —
    traced, not static, so the jit cache keys on (K, M, n_valid, cfg)
    only. Returns (y (n_valid,) f32, habs, participate, noise_std).
    """
    K = X.shape[0]
    X = X.astype(jnp.float32)
    k_chan, k_quant, k_noise = jax.random.split(key, 3)
    habs, participate = sample_channel(k_chan, K, cfg.fade_threshold)

    w = jnp.asarray(weights, jnp.float32) * participate
    w = w / jnp.maximum(jnp.sum(w), 1e-12)

    scale, qmax = _client_grid(bits, jnp.max(jnp.abs(X), axis=1))
    sr_seed = jax.random.bits(k_quant, (), jnp.uint32)

    if use_kernel:
        acc, sumsq = kops.ota_quantize_superpose(X, scale, qmax, w, sr_seed)
    else:
        acc, sumsq = kref.ota_fused_ref(X, scale, qmax, w, sr_seed)

    # receiver AWGN: noise std chosen so that per-element
    # SNR = ||aggregate|| / ||noise|| matches cfg.snr_db. (Padding
    # contributes exact zeros to both acc and sumsq.)
    noise_std = jnp.sqrt(sumsq / n_valid * 10 ** (-cfg.snr_db / 10))
    y = acc[:n_valid] + noise_std * jax.random.normal(k_noise, (n_valid,))
    return y, habs, participate, noise_std


@functools.partial(jax.jit, static_argnames=("cfg",))
def round_channel(key, weights, *, cfg: OTAConfig):
    """Channel draw + FedAvg weight renormalisation (cache keys on K).

    Returns (habs, participate, w) with ``w`` the participation-masked,
    renormalised combining weights in the order of ``weights``. Public
    because the streaming round loop (``fl/server.py``, DESIGN.md §11)
    draws the channel itself at trigger time and hands the final weights
    to ``OtaAccumulator.fold`` — same key split as the one-shot paths,
    so a no-deadline streaming round reproduces their draws exactly.
    """
    k_chan, _, _ = jax.random.split(key, 3)
    habs, participate = sample_channel(k_chan, weights.shape[0], cfg.fade_threshold)
    w = jnp.asarray(weights, jnp.float32) * participate
    w = w / jnp.maximum(jnp.sum(w), 1e-12)
    return habs, participate, w


_round_channel = round_channel  # internal alias (pre-§11 name)


@functools.partial(jax.jit, static_argnames=("cfg", "n_valid"))
def _awgn_epilogue(key, acc, *, cfg: OTAConfig, n_valid: int):
    """Receiver AWGN on the combined aggregate (cache keys on (M, n_valid)).

    Identical to ota_aggregate_flat's epilogue: padding is exact zeros in
    every storage class, so the padded sumsq equals the n_valid one.
    """
    _, _, k_noise = jax.random.split(key, 3)
    sumsq = jnp.sum(acc * acc)
    noise_std = jnp.sqrt(sumsq / n_valid * 10 ** (-cfg.snr_db / 10))
    y = acc[:n_valid] + noise_std * jax.random.normal(k_noise, (n_valid,))
    return y, noise_std


_packed_ref_jit = jax.jit(kref.ota_packed_ref, static_argnames=("qblock", "packed4"))
_fold_ref_jit = jax.jit(kref.ota_fold_ref, static_argnames=("qblock", "packed4"))


def _shard_chunk(M: int, n_shards: int, kinds) -> int:
    """Per-shard column-chunk width for the mesh-sharded fold
    (DESIGN.md §15): ceil(M / n_shards) rounded up so every blockwise
    scale group (qblock columns) and every int4 nibble pair stays whole
    inside one shard's chunk — each shard's local block-id gather and
    nibble unpack are then literally the unsharded ones."""
    align = 2
    for _, qblock in kinds:
        if qblock > 0:
            align = math.lcm(align, int(qblock))
    mc = -(-M // n_shards)
    return -(-mc // align) * align


def _pad_cols(x, width: int, value=0):
    pad = width - x.shape[1]
    if pad <= 0:
        return x
    return jnp.pad(x, ((0, 0), (0, pad)), constant_values=value)


@functools.lru_cache(maxsize=None)
def _sharded_group_program(
    mesh,
    kind: str,
    qblock: int,
    scale_sharded: bool,
    has_acc: bool,
    has_gains: bool,
    use_kernel: bool,
):
    """Build (and cache) the jitted shard_map fold for ONE storage group.

    One executable per group, exactly like the unsharded path's
    ``_packed_ref_jit`` / ``_fold_ref_jit`` calls — this boundary is
    load-bearing for bitwise equality: compiling several group folds
    into one program lets XLA fuse one group's reduction into the next
    group's ``acc + ...`` add (reassociating the float sum, ~1 ulp per
    element, and ``optimization_barrier`` does not stop the rewrite).
    With one group per program the per-shard float program is the
    single-host one verbatim on a column chunk, and
    ``out_specs=P("data")`` makes the cross-shard combine a pure
    concatenation — zero cross-shard float ops (DESIGN.md §15). The
    running state flows between group programs still sharded, so chains
    of groups pay no intermediate gathers. Keyed per group (storage
    class, scale placement, acc/gains presence, backend), so varying
    cohorts reuse compiled programs across rounds exactly like the
    unsharded pieces."""
    from jax.experimental.shard_map import shard_map

    P = jax.sharding.PartitionSpec
    packed4 = kind == "int4"

    def body(*ops):
        it = iter(ops)
        acc = next(it) if has_acc else None
        data, scale, wseg = next(it), next(it), next(it)
        gains = next(it) if has_gains else None
        if acc is None:
            fn = kops.ota_dequant_superpose if use_kernel else _packed_ref_jit
            return fn(data, scale, wseg, gains=gains, qblock=qblock, packed4=packed4)
        fn = kops.ota_fold_packed if use_kernel else _fold_ref_jit
        return fn(acc, data, scale, wseg, gains=gains, qblock=qblock, packed4=packed4)

    in_specs = [P("data")] if has_acc else []
    in_specs += [
        P(None, "data"),
        P(None, "data") if scale_sharded else P(None, None),
        P(),
    ]
    if has_gains:
        in_specs.append(P())
    # check_rep=False: jax 0.4.x has no replication rule for pallas_call,
    # so the kernel path would otherwise refuse to trace under shard_map
    return jax.jit(
        shard_map(
            body,
            mesh=mesh,
            in_specs=tuple(in_specs),
            out_specs=P("data"),
            check_rep=False,
        )
    )


def _fold_groups_sharded(
    acc, kinds, datas, scales, wg, *, gains=None, mesh, use_kernel: bool
):
    """Mesh-sharded ``_fold_groups``: the fold's SYMBOL (column) axis is
    placed across the mesh's ``data`` axis (DESIGN.md §15).

    Each output element of the fold is an independent per-column sum
    over the K rows, so splitting columns never reassociates any float
    sum — every shard runs the identical fused group fold on its chunk
    and the combine is concatenation, making the sharded aggregate
    bit-identical to the single-host oracle by construction. (Splitting
    the K axis instead — per-shard partial superpositions psum'd across
    shards — reassociates the K-sum and is NOT bitwise; see §15.)
    Column chunks are padded to a qblock/nibble-aligned width with
    zero symbols and unit scales, exactly the layout's own padding
    convention, and trimmed after the gather. Per-shard resident symbol
    bytes and fold work drop ~1/n_shards."""
    n_shards = mesh.shape["data"]
    M = 0 if acc is None else acc.shape[0]
    for (kind, _), data in zip(kinds, datas):
        M = max(M, data.shape[1] * (2 if kind == "int4" else 1))
    mc = _shard_chunk(M, n_shards, kinds)
    Mp = mc * n_shards

    def _place(x, *spec):
        # Every operand gets an explicit mesh placement: uplink rows can
        # arrive committed to device 0 (client encode runs on the gathered
        # broadcast params), which a jitted shard_map rejects as a device
        # mismatch. A layout move only — zero float ops.
        return jax.device_put(
            x, jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec(*spec))
        )

    with obs.span("shard_fold", shards=n_shards, groups=len(kinds), chunk=mc):
        running = acc
        if running is not None:
            # re-shard the (gathered, device-0-committed) running state
            # back onto the mesh
            running = _place(jnp.pad(running, (0, Mp - running.shape[0])), "data")
        off = 0
        for (kind, qblock), data, scale in zip(kinds, datas, scales):
            kg = scale.shape[0]
            obs.metrics.inc("ota.rows", kg, kind=kind)
            wseg = wg[off : off + kg]
            gseg = None if gains is None else gains[off : off + kg]
            off += kg
            width = Mp // 2 if kind == "int4" else Mp
            sharded = qblock > 0 and scale.shape[1] > 1
            fn = _sharded_group_program(
                mesh,
                kind,
                qblock,
                sharded,
                running is not None,
                gains is not None,
                use_kernel,
            )
            ops_in = [] if running is None else [running]
            ops_in += [
                _place(_pad_cols(data, width), None, "data"),
                _place(_pad_cols(scale, Mp // qblock, value=1.0), None, "data")
                if sharded
                else _place(scale, None, None),
                _place(wseg),
            ]
            if gseg is not None:
                ops_in.append(_place(gseg))
            running = fn(*ops_in)
        # Gather to ONE device before anything downstream consumes the
        # accumulator: jitted consumers (the AWGN epilogue's sumsq in
        # particular) would otherwise compile *distributed* reductions
        # over the still-sharded array — a different summation tree than
        # the single-host oracle, hence not bitwise. The gather itself
        # is a pure concatenation (zero float ops).
        out = jax.device_put(running, jax.devices()[0])
    return out[:M] if Mp != M else out


def _fold_groups(
    acc, kinds, datas, scales, wg, *, gains=None, mesh=None, use_kernel: bool
):
    """Fold grouped micro-batches into the running superposition ``acc``.

    kinds/datas/scales as produced by ``_group_rows``; ``wg`` the final
    combining weights in group order; ``gains`` the optional per-row
    effective channel gains (DESIGN.md §12), also in group order — when
    None the legacy (gain-free) kernel programs run, byte-identical to
    the pre-channel path. ``acc`` = None starts a fresh accumulator: the
    first group's partial *is* the state (no add with a zeros vector),
    every later group folds in via the fold kernel / oracle
    (``kernels.ota_fold_packed`` / ``ref.ota_fold_ref``) — the exact
    left-associated group sum the pre-§11 barrier loop computed, so the
    synchronous path and a single-batch streaming fold are bit-identical
    by construction.

    Telemetry (DESIGN.md §14): the whole fold runs under one ``fold``
    span, and each storage group bumps the per-storage-class row
    counter ``ota.rows{kind=...}`` — the observation side only; the
    folded values are untouched either way.

    ``mesh``: optional 1-D device mesh with a ``data`` axis
    (``launch.mesh.make_data_mesh``) — routes to the column-sharded
    fold (``_fold_groups_sharded``, span ``shard_fold``), bit-identical
    to this path by construction (DESIGN.md §15).
    """
    if mesh is not None:
        return _fold_groups_sharded(
            acc, kinds, datas, scales, wg, gains=gains, mesh=mesh,
            use_kernel=use_kernel,
        )
    with obs.span("fold", groups=len(kinds)):
        off = 0
        for (kind, qblock), data, scale in zip(kinds, datas, scales):
            kg = scale.shape[0]
            obs.metrics.inc("ota.rows", kg, kind=kind)
            wseg = jax.lax.slice_in_dim(wg, off, off + kg)
            gseg = (
                None if gains is None else jax.lax.slice_in_dim(gains, off, off + kg)
            )
            off += kg
            packed4 = kind == "int4"
            if acc is None:
                fn = kops.ota_dequant_superpose if use_kernel else _packed_ref_jit
                acc = fn(data, scale, wseg, gains=gseg, qblock=qblock, packed4=packed4)
            else:
                fn = kops.ota_fold_packed if use_kernel else _fold_ref_jit
                acc = fn(
                    acc, data, scale, wseg, gains=gseg, qblock=qblock, packed4=packed4
                )
    return acc


def _aggregate_rows_flat(
    key,
    datas,
    scales,
    perm,
    weights,
    *,
    kinds: Tuple[Tuple[str, int], ...],
    cfg: OTAConfig,
    gains=None,
    n_valid: int,
    mesh=None,
    use_kernel: bool = False,
):
    """Aggregate packed uplink rows grouped by wire storage class.

    datas/scales: per-group stacked (Kg, ...) symbol matrices and
    (Kg, n_blocks) quant-scale matrices, ordered per ``kinds`` — a tuple
    of (storage class, qblock) group keys (qblock = 0: per-update
    scales); ``perm`` maps group order back to the cohort's original row
    order (weights/channel stay in cohort order). One fused
    dequant->superpose fold per storage group (``_fold_groups`` — the
    same persistent-accumulator primitive the streaming engine uses,
    DESIGN.md §11), then the shared AWGN epilogue on the combined
    aggregate — same channel, weight renormalisation, and noise-draw
    semantics as ``ota_aggregate_flat``.

    Deliberately NOT one jitted program: the group composition (which
    kinds, how many rows each) changes round to round with the planner's
    bit decisions and dropouts, and a composition-keyed jit would retrace
    per distinct mix. Instead the pieces are jitted on small key spaces —
    channel on K, each group fold on (Kg, kind, qblock), epilogue on
    (M, n_valid) — so a varying cohort reuses compiled code across
    rounds.

    ``gains``: optional (K,) effective channel gains in cohort order
    (``core.channel``, DESIGN.md §12). When given, the physical channel
    REPLACES the legacy coin-flip draw: participation is ``gains > 0``
    (truncated channel inversion), weights renormalise over the
    surviving set (``channel.combine_weights`` — same guard as
    ``round_channel``), and the per-row gain rides inside the fused
    pass. The AWGN epilogue and dither stream are untouched either way.
    """
    if gains is None:
        habs, participate, w = round_channel(key, weights, cfg=cfg)
        gg = None
    else:
        gains = jnp.asarray(gains, jnp.float32)
        participate = gains > 0
        habs = None
        w = chan.combine_weights(weights, gains)
        gg = gains[perm]  # group-order view of the per-row gains
    wg = w[perm]  # group-order view of the cohort weights
    acc = _fold_groups(
        None, kinds, datas, scales, wg, gains=gg, mesh=mesh, use_kernel=use_kernel
    )
    with obs.span("finalize"):
        y, noise_std = _awgn_epilogue(key, acc, cfg=cfg, n_valid=n_valid)
    return y, habs, participate, noise_std


def _group_rows(rows: Sequence[packing.PackedRow]):
    """Stable-sort rows by (storage class, qblock) -> groups.

    Returns (kinds, datas, scales, perm) where kinds is a tuple of
    (kind, qblock) keys. Rows sharing a storage class but quantized with
    different block sizes (a mixed-planner round) land in separate
    groups — their (Kg, n_blocks) scale matrices have different widths,
    and each group's fused pass gets its own static qblock.
    """

    def _key(i):
        return (packing.KIND_RANK[rows[i].kind], rows[i].qblock)

    order = sorted(range(len(rows)), key=_key)
    kinds, datas, scales, perm = [], [], [], []
    i = 0
    while i < len(order):
        kind, qblock = rows[order[i]].kind, rows[order[i]].qblock
        grp = [j for j in order[i:] if _key(j) == _key(order[i])]
        kinds.append((kind, qblock))
        datas.append(jnp.stack([rows[j].data for j in grp]))
        scales.append(
            jnp.stack([jnp.atleast_1d(jnp.asarray(rows[j].scale)) for j in grp])
        )
        perm.extend(grp)
        i += len(grp)
    return tuple(kinds), tuple(datas), tuple(scales), jnp.asarray(perm, jnp.int32)


def staleness_weights(delays, grace: float, *, gamma: float = 0.5) -> jnp.ndarray:
    """Staleness discount for rows arriving ``delays`` seconds after the
    round's aggregation trigger (DESIGN.md §11).

    Exponential in the normalised lag: gamma ** (delay / grace), so a row
    landing right at the trigger keeps weight ~1 and one landing at the
    end of the grace window keeps ``gamma``. Clipped to [gamma, 1] —
    rows past the grace window should not be folded at all (the round
    plan drops them), so the discount never decays below the end-of-
    window value.
    """
    d = jnp.asarray(delays, jnp.float32)
    g = jnp.float32(max(float(grace), 1e-9))
    return jnp.clip(jnp.float32(gamma) ** (d / g), min(gamma, 1.0), 1.0)


class OtaAccumulator:
    """Persistent superposition accumulator for streaming rounds
    (DESIGN.md §11).

    Owns the running (padded_size,) pre-noise aggregate the buffered
    round loop folds arrivals into: ``fold`` takes one micro-batch of
    ``packing.PackedRow`` uplinks with their *final* combining weights
    (participation-masked and renormalised — see ``round_channel`` — and
    optionally staleness-discounted), groups it by (storage class,
    qblock) exactly like the one-shot path, and folds each group through
    the fused fold kernel / oracle. ``finalize`` runs the shared AWGN
    epilogue (the aggregate's norm state — the noise-power calibration
    input — is derived from the persistent accumulator itself, the same
    jitted program the barrier path uses) and unpacks to the update
    pytree.

    Equivalence contract: folding the whole arrival set as ONE batch, in
    cohort order, with ``round_channel``-normalised weights and the same
    round key, is bit-identical to ``ota_aggregate_packed`` — the
    synchronous path *is* ``_fold_groups`` now, so the no-deadline
    streaming round and the barrier round run the same float ops in the
    same order. Multi-batch folds (the async path: late arrivals folding
    in after the trigger) left-associate batch partials instead, which
    is the documented semantic difference, not a bug.
    """

    def __init__(
        self,
        layout: packing.Layout,
        cfg: OTAConfig = OTAConfig(),
        *,
        mesh=None,
        use_kernel: Optional[bool] = None,
    ):
        self.layout = layout
        self.cfg = cfg
        # optional data-axis mesh: every fold shards its symbol axis
        # (DESIGN.md §15), bit-identical to the single-host fold
        self.mesh = mesh
        self.use_kernel = _use_kernel_default() if use_kernel is None else use_kernel
        self.reset()

    def reset(self) -> None:
        """Clear the running state (fresh round)."""
        self._acc = None
        self.n_folded = 0
        self.wire_bytes = 0

    @property
    def accumulator(self) -> jnp.ndarray:
        """The running (padded_size,) pre-noise aggregate (zeros before
        any fold)."""
        if self._acc is None:
            return jnp.zeros((self.layout.padded_size,), jnp.float32)
        return self._acc

    def fold(
        self, rows: Sequence[packing.PackedRow], weights, *, staleness=None, gains=None
    ) -> "OtaAccumulator":
        """Fold one micro-batch of packed uplink rows into the state.

        weights: final per-row combining weights (already channel-masked
        and renormalised by the caller); ``staleness``: optional per-row
        discount multipliers (``staleness_weights``) for late arrivals;
        ``gains``: optional per-row effective channel gains
        (``core.channel``, DESIGN.md §12) riding inside the fused fold —
        None is byte-identical to the pre-channel fold, and a wave of
        all-truncated rows (all gains 0) adds exact zeros, leaving the
        accumulator value bit-unchanged. Rows are grouped by (storage
        class, qblock) and each group runs one fused fold pass — no
        (K, M) f32 matrix ever materialises. Returns self for chaining:
        fold(fold(state, b0), b1)...
        """
        if len(rows) == 0:
            return self
        w = jnp.asarray(weights, jnp.float32)
        if staleness is not None:
            for s in staleness:  # late-arrival discount distribution (§14)
                obs.metrics.observe("stream.staleness_discount", float(s))
            w = w * jnp.asarray(staleness, jnp.float32)
        kinds, datas, scales, perm = _group_rows(rows)
        g = None if gains is None else jnp.asarray(gains, jnp.float32)[perm]
        self._acc = _fold_groups(
            self._acc,
            kinds,
            datas,
            scales,
            w[perm],
            gains=g,
            mesh=self.mesh,
            use_kernel=self.use_kernel,
        )
        self.n_folded += len(rows)
        self.wire_bytes += int(sum(r.wire_nbytes for r in rows))
        return self

    def finalize(self, key) -> Tuple[Pytree, "AggregateInfo"]:
        """AWGN epilogue on the accumulated superposition.

        Same key-split, noise draw, and norm calibration as the one-shot
        paths (``_awgn_epilogue``). Returns (update pytree with f32
        leaves, ``AggregateInfo``); the accumulator stays intact — call
        ``reset`` to start the next round.
        """
        assert self._acc is not None, "finalize() before any fold()"
        with obs.span("finalize"):
            y, noise_std = _awgn_epilogue(
                key, self._acc, cfg=self.cfg, n_valid=self.layout.size
            )
        info = AggregateInfo(
            noise_std=float(noise_std),
            n_folded=self.n_folded,
            uplink_bytes=self.wire_bytes,
            uplink_bytes_f32=4 * self.layout.padded_size * self.n_folded,
        )
        info.publish()
        return packing.unpack(y, self.layout, cast=False), info


@dataclasses.dataclass
class AggregateInfo(Mapping):
    """Typed per-aggregation report (PR 8; previously an untyped dict).

    One class serves every aggregation entry point — the one-shot paths
    (``ota_aggregate_packed`` / ``ota_aggregate_flat`` callers), the
    streaming ``OtaAccumulator.finalize``, and the per-tree oracle —
    with fields a given path doesn't produce left ``None``. It
    implements the ``Mapping`` protocol over its *present* (non-None)
    fields, so the established ``info["uplink_bytes"]`` /
    ``"n_truncated" in info`` call sites and tests keep working
    unchanged; new code should prefer the attributes.
    """

    noise_std: float
    n_participating: Optional[int] = None
    participation: Optional[list] = None
    channel_abs: Optional[list] = None  # legacy coin-flip channel |h| draws
    channel_gains: Optional[list] = None  # physical-channel effective gains
    n_truncated: Optional[int] = None
    n_folded: Optional[int] = None  # streaming accumulator rows folded
    uplink_bytes: Optional[int] = None
    uplink_bytes_f32: Optional[int] = None
    downlink_bytes: Optional[int] = None  # filled by the FL round loop

    def _present(self) -> Dict[str, Any]:
        return {
            f.name: getattr(self, f.name)
            for f in dataclasses.fields(self)
            if getattr(self, f.name) is not None
        }

    def __getitem__(self, key: str) -> Any:
        return self._present()[key]

    def __iter__(self):
        return iter(self._present())

    def __len__(self) -> int:
        return len(self._present())

    def publish(self, registry=None) -> None:
        """Push this aggregation's numbers into the metrics registry
        (DESIGN.md §14) — the ``obs.metrics`` side of the report.

        Counters accumulate across rounds (``ota.uplink_bytes``,
        ``ota.rows_truncated``, ``ota.aggregations``); gauges carry the
        latest round (``ota.noise_std``, ``ota.truncation_rate``,
        ``ota.mean_misalignment``). The truncation rate covers both
        channel paths: the physical model's truncated-inversion count
        (``n_truncated``) and the legacy coin-flip's non-participating
        fraction come out of the same participation vector.
        """
        m = registry or obs.metrics.REGISTRY
        m.inc("ota.aggregations")
        m.set_gauge("ota.noise_std", self.noise_std)
        if self.uplink_bytes is not None:
            m.inc("ota.uplink_bytes", self.uplink_bytes)
        if self.n_folded is not None:
            m.inc("ota.rows_folded", self.n_folded)
        if self.n_participating is not None:
            m.set_gauge("ota.n_participating", self.n_participating)
        if self.participation:
            k = len(self.participation)
            n_trunc = (
                self.n_truncated
                if self.n_truncated is not None
                else k - sum(bool(p) for p in self.participation)
            )
            m.set_gauge("ota.truncation_rate", n_trunc / k)
            if n_trunc:
                m.inc("ota.rows_truncated", n_trunc)
        if self.channel_gains:
            alive = [g for g in self.channel_gains if g > 0]
            if alive:
                miss = sum(1.0 - g for g in alive) / len(alive)
                m.set_gauge("ota.mean_misalignment", miss)


def _info(habs, participate, noise_std, **kw) -> AggregateInfo:
    participate = jax.device_get(participate)
    return AggregateInfo(
        noise_std=float(noise_std),
        n_participating=int(participate.sum()),
        participation=[bool(p) for p in participate],
        channel_abs=[float(h) for h in jax.device_get(habs)],
        **kw,
    )


def ota_aggregate_packed(
    key,
    X,
    bits: Sequence[int],
    weights: Sequence[float],
    layout: packing.Layout,
    cfg: OTAConfig = OTAConfig(),
    *,
    gains=None,
    mesh=None,
    use_kernel: Optional[bool] = None,
) -> Tuple[Pytree, "AggregateInfo"]:
    """Aggregate pre-packed client rows; unpack the result per ``layout``.

    The entry point for callers that already hold flat updates (the FL
    server packs each client's delta exactly once, at the client). ``X``
    is either the legacy (K, M) f32 matrix — quantization then happens
    inside the fused pass — or a sequence of ``packing.PackedRow``
    produced by ``quantize_uplink`` with this round's ``derive_sr_seed``;
    then the rows arrive already quantized+bit-packed and the pass only
    dequantizes (DESIGN.md §5-§6). Same round key => identical aggregate
    either way (same dither stream, channel, and noise draws).

    ``gains``: optional (K,) effective channel gains from the physical
    channel model (``core.channel``, DESIGN.md §12) — packed rows only.
    When given it replaces the legacy participation coin-flip:
    truncated rows (gain 0) are excluded from the weight normaliser and
    contribute exact zeros, surviving rows superpose scaled by their
    misalignment gain inside the fused pass. ``gains=None`` is bitwise
    identical to the pre-channel aggregation for the same round key.

    ``mesh``: optional ``data``-axis device mesh
    (``launch.mesh.make_data_mesh``) — packed rows only. The fold's
    symbol axis shards across the mesh and the aggregate stays
    bit-identical to the single-host path (DESIGN.md §15); the AWGN
    epilogue runs unsharded on the gathered accumulator, so channel,
    weights, and noise draws are untouched.
    """
    if use_kernel is None:
        use_kernel = _use_kernel_default()
    if packing.is_packed_rows(X):
        rows: Sequence[packing.PackedRow] = X
        if bits is not None:
            assert [int(b) for b in bits] == [r.bits for r in rows], (
                "bits arg disagrees with PackedRow.bits"
            )
        kinds, datas, scales, perm = _group_rows(rows)
        y, habs, participate, noise_std = _aggregate_rows_flat(
            key,
            datas,
            scales,
            perm,
            jnp.asarray(weights, jnp.float32),
            kinds=kinds,
            cfg=cfg,
            gains=gains,
            n_valid=layout.size,
            mesh=mesh,
            use_kernel=use_kernel,
        )
        wire_kw = dict(
            uplink_bytes=wire.wire_bytes(rows),
            uplink_bytes_f32=4 * layout.padded_size * len(rows),
        )
        if gains is None:
            info = _info(habs, participate, noise_std, **wire_kw)
        else:
            participate = jax.device_get(participate)
            info = AggregateInfo(
                noise_std=float(noise_std),
                n_participating=int(participate.sum()),
                participation=[bool(p) for p in participate],
                n_truncated=int((~participate).sum()),
                channel_gains=[float(g) for g in jax.device_get(gains)],
                **wire_kw,
            )
    else:
        assert gains is None, (
            "gains= is a packed-uplink feature (PackedRow cohorts only)"
        )
        assert mesh is None, (
            "mesh= is a packed-uplink feature (PackedRow cohorts only)"
        )
        y, habs, participate, noise_std = ota_aggregate_flat(
            key,
            X,
            jnp.asarray(bits, jnp.int32),
            jnp.asarray(weights, jnp.float32),
            cfg=cfg,
            n_valid=layout.size,
            use_kernel=use_kernel,
        )
        info = _info(habs, participate, noise_std)
    info.publish()
    agg = packing.unpack(y, layout, cast=False)
    return agg, info


def ota_aggregate(
    key,
    updates: Sequence[Pytree],
    bits: Sequence[int],
    weights: Sequence[float],
    cfg: OTAConfig = OTAConfig(),
    *,
    layout: Optional[packing.Layout] = None,
    use_kernel: Optional[bool] = None,
) -> Tuple[Pytree, "AggregateInfo"]:
    """Aggregate client update pytrees over the simulated OTA channel.

    updates: per-client pytrees (same structure). bits: per-client precision.
    weights: FedAvg weights (sum need not be 1; renormalised over the
    participating set after fade truncation).

    Packs once into the (K, M) matrix and runs the fused flat pipeline
    (module docstring). Returns (aggregated update pytree with f32 leaves,
    info dict with participation/noise stats).

    ``updates`` may also be a sequence of ``packing.PackedRow`` (already
    quantized+bit-packed uplinks, see ``quantize_uplink``); then
    ``layout`` is required — there is no pytree to derive it from.
    """
    if packing.is_packed_rows(updates):
        assert layout is not None, "packed rows need an explicit layout"
        return ota_aggregate_packed(
            key, updates, bits, weights, layout, cfg, use_kernel=use_kernel
        )
    if layout is None:
        layout = packing.make_layout(updates[0])
    X = packing.pack_batch(updates, layout)
    return ota_aggregate_packed(
        key, X, bits, weights, layout, cfg, use_kernel=use_kernel
    )


def ota_aggregate_pertree(
    key,
    updates: Sequence[Pytree],
    bits: Sequence[int],
    weights: Sequence[float],
    cfg: OTAConfig = OTAConfig(),
) -> Tuple[Pytree, "AggregateInfo"]:
    """Reference oracle: the legacy per-client/per-leaf Python loop.

    Semantically identical to the flat path — same stochastic-rounding
    dither (the positional hash of ``kernels.ota_fused.sr_dither``
    evaluated over the flat layout and sliced per leaf), same receiver
    noise draw, same shared per-update analog grid — but dispatched as
    O(clients x leaves) unjitted ops. Kept for equivalence tests and as
    the readable specification of the data plane; production goes through
    ``ota_aggregate``.
    """
    n = len(updates)
    layout = packing.make_layout(updates[0])
    k_chan, k_quant, k_noise = jax.random.split(key, 3)
    habs, participate = sample_channel(k_chan, n, cfg.fade_threshold)

    w = jnp.asarray(weights, jnp.float32) * participate
    w = w / jnp.maximum(jnp.sum(w), 1e-12)

    from repro.kernels.ota_fused import sr_dither

    sr_seed = jax.random.bits(k_quant, (), jnp.uint32)
    positions = jnp.arange(layout.padded_size, dtype=jnp.uint32)
    leaves0, treedef = jax.tree.flatten(updates[0])
    agg_leaves = [jnp.zeros_like(l, jnp.float32) for l in leaves0]
    for i in range(n):
        leaves_i = jax.tree.leaves(updates[i])
        b = int(bits[i])
        if b >= 32:
            dq_leaves = [l.astype(jnp.float32) for l in leaves_i]
        else:
            qmax = float(quant.qrange(b))
            amax = jnp.max(
                jnp.stack([jnp.max(jnp.abs(l.astype(jnp.float32))) for l in leaves_i])
            )
            scale = jnp.maximum(amax, 1e-12) / qmax
            u_full = sr_dither(sr_seed, jnp.uint32(i), positions)
            dq_leaves = []
            for leaf, off, size, shape in zip(
                leaves_i, layout.offsets, layout.sizes, layout.shapes
            ):
                u = jax.lax.slice_in_dim(u_full, off, off + size).reshape(shape)
                scaled = leaf.astype(jnp.float32) / scale
                floor = jnp.floor(scaled)
                q = floor + (u < (scaled - floor)).astype(jnp.float32)
                q = jnp.clip(q, -qmax, qmax)
                dq_leaves.append(q * scale)
        wi = w[i]
        agg_leaves = [a + wi * l for a, l in zip(agg_leaves, dq_leaves)]

    total_elems = layout.size
    agg_norm2 = sum(jnp.sum(l**2) for l in agg_leaves)
    noise_std = jnp.sqrt(agg_norm2 / total_elems * 10 ** (-cfg.snr_db / 10))
    n_full = jax.random.normal(k_noise, (total_elems,))
    noisy = [
        a + noise_std * jax.lax.slice_in_dim(n_full, off, off + size).reshape(a.shape)
        for a, off, size in zip(agg_leaves, layout.offsets, layout.sizes)
    ]
    return jax.tree.unflatten(treedef, noisy), _info(habs, participate, noise_std)


def channel_uses(
    bits: Sequence[int], n_params: int, cfg: OTAConfig = OTAConfig()
) -> int:
    """OTA channel uses for one aggregation round.

    Mixed-precision modulation shares symbols across precisions: the round
    costs n_params symbols at the *max* participating precision's
    constellation — clients at lower b simply use coarser points. (This is
    the "quantization overhead covered by OTA" property: cost does NOT sum
    over clients.)
    """
    return n_params


def digital_uplink_bits(bits: Sequence[int], n_params: int) -> int:
    """Baseline comparison: digital per-client uplink cost (sums over clients)."""
    return int(sum(int(b) * n_params for b in bits))
