"""Symmetric wire codec: ONE encode/decode facade for both legs
(DESIGN.md §6, §13).

Until PR 8 the quantize/pack/dequant pipeline was spelled per leg: the
uplink went through ``ota.quantize_uplink`` (clients) and the fused
in-pass dequant (server), while the downlink broadcast shipped raw f32
and had no codec at all. This module is the single seam both legs now
route through:

- ``encode_rows`` / ``encode_row``: stochastic-quantize a flat f32 row
  at ``bits`` with a shared positional dither stream
  (``core.quant.quantize_row_sr``) and bit-pack the symbols into a
  ``packing.PackedRow`` — int4 two symbols per byte, int8/int16/int32
  above, f32 passthrough for ``bits`` >= 32 (byte-identical to an
  uncoded transfer, the equivalence oracle). ``block`` > 0 ships
  blockwise scales (one f32 per ``block`` symbols).
- ``decode_rows`` / ``decode_row``: reconstruct the f32 row
  (q * scale[block]) — the same math the fused aggregation pass
  (``kernels/ota_fused.ota_packed_2d`` / ``kernels/ref.ota_packed_ref``)
  applies in-tile, so a host-side decode and the in-kernel dequant agree
  bit-for-bit on the same ``PackedRow``.

Leg mapping:

- **Uplink**: clients encode their update row with the round's uplink
  dither seed (``ota.derive_sr_seed``; ``first_row`` = the client's row
  in the cohort) and the server never decodes on the host — rows feed
  the fused dequant+superpose pass directly. ``decode_rows`` is the
  measurement/oracle path (quantization-error reports, tests).
- **Downlink** (DESIGN.md §13): the server encodes the round's global
  param delta ONCE with the downlink dither seed (``ota.derive_dl_seed``,
  a stream disjoint from the uplink's), broadcasts the single
  ``PackedRow``, and every client decodes it — decoding is
  deterministic given the row, so the whole fleet reconstructs
  bit-identical params.

Encoding is deterministic given (row, bits, seed, row index, block), and
decoding is a pure function of the encoded row — any two decoders of one
encoded row agree bit-for-bit.
"""

from __future__ import annotations

from typing import List, Optional, Sequence

import jax.numpy as jnp

from repro import obs
from repro.core import packing, quant
from repro.kernels import ops as kops


def encode_row(
    row: jnp.ndarray,
    bits: int,
    seed: jnp.ndarray,
    row_index: int,
    *,
    block: int = 0,
) -> packing.PackedRow:
    """Encode one flat f32 row into its wire form at ``bits``.

    ``seed``/``row_index`` select the positional dither stream
    (``quant.quantize_row_sr``): uplink rows use the round's
    ``ota.derive_sr_seed`` with their cohort row index, the downlink
    broadcast uses ``ota.derive_dl_seed`` with row 0. ``block`` > 0
    quantizes with blockwise scales (one f32 per ``block`` symbols,
    +4 bytes/block on the wire); 0 is the per-row scalar scale.
    ``bits`` >= 32 (and <= 1, the empty symmetric grid) is the f32
    passthrough: ``data`` is the row itself, byte-identical to an
    uncoded transfer.
    """
    q, scale = quant.quantize_row_sr(row, bits, seed, row_index, block=block)
    if packing.wire_kind(bits) == "int4":
        q = kops.pack_int4_rows(q)
    qblock = block if int(jnp.asarray(scale).size) > 1 else 0
    out = packing.PackedRow(data=q, scale=scale, bits=int(bits), qblock=qblock)
    if obs.is_enabled() and out.kind != "float32":
        # quantization-MSE proxy (DESIGN.md §14): uniform-dither noise
        # power E[scale^2]/12 per symbol, from the encoded scales alone —
        # no reconstruction pass. Device sync, so telemetry-mode only;
        # the encoded row is identical either way.
        s = jnp.atleast_1d(jnp.asarray(scale, jnp.float32))
        obs.metrics.observe(
            "wire.quant_mse_proxy", float(jnp.mean(s * s)) / 12.0, kind=out.kind
        )
    return out


def decode_row(row: packing.PackedRow, n: Optional[int] = None) -> jnp.ndarray:
    """Reconstruct the f32 row a ``PackedRow`` encodes (q * scale[block]).

    Deterministic: every decoder of the same row produces bit-identical
    output — the property the compressed downlink's fleet-wide param
    consistency rests on. ``n`` trims to the logical (unpadded) length.
    """
    if row.kind == "float32":
        out = jnp.asarray(row.data, jnp.float32)
        return out if n is None else out[:n]
    q = row.data
    if row.kind == "int4":
        q = kops.unpack_int4_rows(q)
    q = q.astype(jnp.float32)
    scales = jnp.atleast_1d(jnp.asarray(row.scale, jnp.float32))
    if row.qblock > 0 and scales.shape[0] > 1:
        bid = jnp.arange(q.shape[0], dtype=jnp.int32) // row.qblock
        out = q * jnp.take(scales, bid, mode="clip")
    else:
        out = q * scales[0]
    return out if n is None else out[:n]


def decode_broadcast(
    row: packing.PackedRow,
    base: Optional[jnp.ndarray] = None,
    n: Optional[int] = None,
) -> jnp.ndarray:
    """Client-side downlink reconstruction (DESIGN.md §13).

    An f32 passthrough broadcast carries the ABSOLUTE params vector —
    the decode IS the params, bit-identical to the legacy uncompressed
    broadcast (``a + fl(b - a) != b`` in floats, so passthrough never
    routes through a delta). A quantized broadcast carries the round's
    global delta against ``base`` (the fleet's current replica), and the
    reconstruction is ``base + decode(row)``. Every client holds the
    same ``base`` and decoding is deterministic, so the whole fleet —
    and the server, which adopts the same reconstruction — lands on
    bit-identical params.
    """
    decoded = decode_row(row, n)
    if row.kind == "float32":
        return decoded
    assert base is not None, "quantized broadcast needs the current replica"
    return jnp.asarray(base, jnp.float32)[: decoded.shape[0]] + decoded


def encode_rows(
    rows: Sequence[jnp.ndarray],
    bits: Sequence[int],
    seed: jnp.ndarray,
    *,
    block: int = 0,
    first_row: int = 0,
) -> List[packing.PackedRow]:
    """Encode a batch of flat rows; row ``j`` dithers as row
    ``first_row + j`` of ``seed``'s stream."""
    assert len(rows) == len(bits), (len(rows), len(bits))
    return [
        encode_row(r, int(b), seed, first_row + j, block=block)
        for j, (r, b) in enumerate(zip(rows, bits))
    ]


def decode_rows(
    rows: Sequence[packing.PackedRow], n: Optional[int] = None
) -> List[jnp.ndarray]:
    """Decode a batch of wire rows back to f32 (see ``decode_row``)."""
    return [decode_row(r, n) for r in rows]


def wire_bytes(rows: Sequence[packing.PackedRow]) -> int:
    """Total bytes the encoded rows occupy on the wire."""
    return int(sum(r.wire_nbytes for r in rows))
