"""Pytree <-> flat-vector packing with a static, hashable ``Layout``.

The MP-OTA-FL data plane works on a flat ``(K, M)`` client-update matrix:
every client's update pytree is raveled into one padded f32 vector so the
whole round — quantize, superpose, noise — is a single device program
instead of an O(clients x leaves) dispatch storm. The same layout is the
natural wire/storage format for checkpointing and serving weight pushes,
so it lives in ``core`` rather than next to the OTA kernels.

A ``Layout`` is derived once per tree structure (``make_layout``) and is
fully static: treedef, per-leaf shapes/dtypes/offsets, and the padded
total length (rounded up to a lane-block multiple so packed vectors drop
straight into the Pallas kernels without re-padding). ``Layout`` is
hashable, so jitted functions can take it as a static argument and the
jit cache keys on the layout identity.

The flat vector is f32: every leaf round-trips through float32, so
integer leaves are exact only up to the 24-bit mantissa (|v| <= 2^24).
Fine for update/weight trees (the data plane) and f32/bf16 params;
trees carrying large integer state (step counters, RNG keys) need a
side channel.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Sequence, Tuple

import jax
import jax.numpy as jnp

Pytree = Any

# Matches kernels.ota_fused.BLOCK_COLS: packed vectors tile evenly into the
# fused aggregation kernel's (K, block) grid with no second padding pass.
DEFAULT_BLOCK = 2048

# Default *quantization* block for blockwise uplink scales (DESIGN.md §6):
# symbols per scale on the wire. Distinct from DEFAULT_BLOCK (the lane-pad
# granularity of the flat layout). 256 symbols/scale costs +4 bytes per
# 256 symbols — for int4 that is 1/64 of the symbol bytes — while capping
# how far one outlier leaf can inflate the shared integer grid.
QUANT_BLOCK = 256


@dataclasses.dataclass(frozen=True)
class Layout:
    """Static description of a pytree's flat packing.

    offsets[i] is leaf i's start in the flat vector; ``size`` is the real
    (unpadded) element count and ``padded_size`` the lane-aligned length.
    Frozen + all-hashable fields => usable as a jit static argument.
    """

    treedef: Any
    shapes: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[str, ...]
    sizes: Tuple[int, ...]
    offsets: Tuple[int, ...]
    size: int
    padded_size: int
    block: int

    @property
    def n_leaves(self) -> int:
        return len(self.shapes)

    @property
    def padding(self) -> int:
        return self.padded_size - self.size


# --------------------------------------------------------------------------
# Packed uplink wire format (DESIGN.md §6)
# --------------------------------------------------------------------------

# storage class per planned precision: the smallest wire dtype that holds
# the symmetric integer symbols. int4 is two symbols per byte
# (kernels.ops.pack_int4_rows). bits <= 1 has an empty symmetric grid
# (qmax = 2^(b-1) - 1 = 0) and rides through unquantized, exactly like
# the fused f32 path's qmax == 0 passthrough; bits >= 32 is unquantized
# by definition. 17..31 bits quantize like every other level — int32
# symbols save no bytes over f32 but keep the packed/flat equivalence.
def wire_kind(bits: int) -> str:
    """"int4"|"int8"|"int16"|"int32"|"float32" for a b-bit uplink row."""
    if bits <= 1 or bits >= 32:
        return "float32"
    if bits <= 4:
        return "int4"
    if bits <= 8:
        return "int8"
    if bits <= 16:
        return "int16"
    return "int32"


# public: core/ota groups cohort rows by this ordering (densest first)
KIND_RANK = {"int4": 0, "int8": 1, "int16": 2, "int32": 3, "float32": 4}


def n_scale_blocks(block: int, padded_size: int) -> int:
    """Scales a blockwise row ships: ceil(M / block); 1 when per-row."""
    if block <= 0 or block >= padded_size:
        return 1
    return -(-padded_size // block)


def row_wire_bytes(bits: int, padded_size: int, block: int = 0) -> int:
    """Bytes one client's packed row occupies on the wire.

    Quantized rows carry their symbols plus one f32 scale per
    quantization block — ``block`` = 0 (per-row, the PR-2 format) ships
    exactly one; blockwise ships ceil(padded_size / block), i.e.
    +4 bytes per ``block`` symbols. The f32 passthrough row is just the
    symbols.
    """
    kind = wire_kind(bits)
    if kind == "float32":
        return 4 * padded_size
    nscales = n_scale_blocks(block, padded_size)
    if kind == "int4":  # two symbols per byte, odd length rounds up
        return (padded_size + 1) // 2 + 4 * nscales
    per = {"int8": 1, "int16": 2, "int32": 4}[kind]
    return per * padded_size + 4 * nscales


@dataclasses.dataclass(frozen=True)
class PackedRow:
    """One client's uplink in wire form: quantized symbols + analog grid.

    data: (padded_size//2,) uint8 for a 4-bit client (two symbols per
    byte, ``kernels.ops.pack_int4_rows``), (padded_size,) int8/int16/
    int32 for 5..8 / 9..16 / 17..31 bits, or the (padded_size,) f32 row
    for an unquantized client (bits >= 32, or <= 1 where the symmetric
    grid is empty). scale is the f32 analog grid step: the () per-update
    scalar of the PR-2 format (the ``qblock`` = 0 degenerate case — old
    rows parse unchanged), or an (n_blocks,) vector of per-block scales
    where symbol position p belongs to block p // qblock (last block
    ragged over the zero-pad region). 1 for f32 rows. bits is the
    planned precision. Dequantization (q * scale[block]) happens inside
    the fused aggregation pass (``kernels/ota_fused.ota_packed_2d`` /
    ``kernels/ref.ota_packed_ref``) — the f32 row never exists between
    client and server.
    """

    data: jnp.ndarray
    scale: jnp.ndarray
    bits: int
    qblock: int = 0  # symbols per scale block; 0 = one per-update scale

    @property
    def kind(self) -> str:
        return wire_kind(self.bits)

    @property
    def n_scales(self) -> int:
        """Scale entries on the wire (1 for the per-row format)."""
        return max(int(jnp.asarray(self.scale).size), 1)

    @property
    def wire_nbytes(self) -> int:
        n = int(self.data.size) * jnp.dtype(self.data.dtype).itemsize
        return n if self.kind == "float32" else n + 4 * self.n_scales


def is_packed_rows(x: Any) -> bool:
    """True when ``x`` is a sequence of ``PackedRow`` (vs a (K, M) matrix)."""
    return (
        isinstance(x, (list, tuple))
        and len(x) > 0
        and all(isinstance(r, PackedRow) for r in x)
    )


def make_layout(tree: Pytree, block: int = DEFAULT_BLOCK) -> Layout:
    """Derive the static flat layout of ``tree`` (leaf order = treedef order)."""
    leaves, treedef = jax.tree.flatten(tree)
    shapes, dtypes, sizes, offsets = [], [], [], []
    off = 0
    for leaf in leaves:
        leaf = jnp.asarray(leaf)
        shapes.append(tuple(int(d) for d in leaf.shape))
        dtypes.append(jnp.dtype(leaf.dtype).name)
        n = int(leaf.size)
        sizes.append(n)
        offsets.append(off)
        off += n
    padded = -(-max(off, 1) // block) * block
    return Layout(
        treedef=treedef,
        shapes=tuple(shapes),
        dtypes=tuple(dtypes),
        sizes=tuple(sizes),
        offsets=tuple(offsets),
        size=off,
        padded_size=padded,
        block=block,
    )


@functools.partial(jax.jit, static_argnames=("layout",))
def pack(tree: Pytree, layout: Layout) -> jnp.ndarray:
    """Ravel + concat + zero-pad ``tree`` into a ``(padded_size,)`` f32 vector."""
    leaves = jax.tree.leaves(tree)
    assert len(leaves) == layout.n_leaves, (len(leaves), layout.n_leaves)
    flat = [jnp.asarray(l).astype(jnp.float32).reshape(-1) for l in leaves]
    if layout.padding:  # padded_size >= block, so an empty tree is all pad
        flat.append(jnp.zeros((layout.padding,), jnp.float32))
    return jnp.concatenate(flat)


@functools.partial(jax.jit, static_argnames=("layout", "cast"))
def unpack(flat: jnp.ndarray, layout: Layout, *, cast: bool = True) -> Pytree:
    """Inverse of ``pack``. ``cast=False`` keeps every leaf f32 (the OTA
    aggregation path hands f32 aggregates to the server optimizer)."""
    leaves = []
    for shape, dtype, off, size in zip(
        layout.shapes, layout.dtypes, layout.offsets, layout.sizes
    ):
        leaf = jax.lax.slice_in_dim(flat, off, off + size).reshape(shape)
        leaves.append(leaf.astype(dtype) if cast else leaf)
    return jax.tree.unflatten(layout.treedef, leaves)


def pack_batch(trees: Sequence[Pytree], layout: Layout) -> jnp.ndarray:
    """Stack K packed client updates into the ``(K, padded_size)`` matrix."""
    return jnp.stack([pack(t, layout) for t in trees])
