"""Uniform affine quantization — the MP-OTA-FL data-plane primitive.

Precision levels follow ``repro.configs.PRECISION_LEVELS`` ({4, 8, 16, 32}
bits). 32 means "no quantization". Per-tensor symmetric scales (the
mixed-precision modulation scheme of the paper's ref [2] aligns symmetric
integer grids across clients, so symmetric quantization is the faithful
choice).

The jnp implementations here are the *reference semantics*; the Pallas
kernels in ``repro.kernels`` implement the same ops for TPU and are tested
against these (see kernels/*/ref.py which re-export from here).
"""

from __future__ import annotations

import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


def qrange(bits: int) -> int:
    """Symmetric integer range: values in [-qmax, qmax]."""
    return 2 ** (bits - 1) - 1


def quantize(
    x: jnp.ndarray, bits: int, *, key: Optional[jax.Array] = None
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-tensor symmetric quantization.

    Returns (q int32, scale f32 scalar). With ``key``, rounding is
    stochastic (unbiased — the property OTA aggregation relies on: the
    expected dequantized sum equals the true sum).
    """
    if bits >= 32:
        return x.astype(jnp.float32), jnp.ones((), jnp.float32)
    qmax = qrange(bits)
    amax = jnp.max(jnp.abs(x)).astype(jnp.float32)
    scale = jnp.maximum(amax, 1e-12) / qmax
    scaled = x.astype(jnp.float32) / scale
    if key is not None:
        floor = jnp.floor(scaled)
        frac = scaled - floor
        rnd = jax.random.uniform(key, x.shape)
        q = floor + (rnd < frac).astype(jnp.float32)
    else:
        q = jnp.round(scaled)
    q = jnp.clip(q, -qmax, qmax)
    return q.astype(jnp.int32), scale


def dequantize(q: jnp.ndarray, scale: jnp.ndarray, bits: int) -> jnp.ndarray:
    if bits >= 32:
        return q.astype(jnp.float32)
    return q.astype(jnp.float32) * scale


def fake_quant(
    x: jnp.ndarray, bits: int, *, key: Optional[jax.Array] = None
) -> jnp.ndarray:
    """quantize → dequantize (the client-side model degradation at level b)."""
    if bits >= 32:
        return x
    q, scale = quantize(x, bits, key=key)
    return dequantize(q, scale, bits).astype(x.dtype)


_STORAGE_DTYPE = {
    "int4": jnp.int8,
    "int8": jnp.int8,
    "int16": jnp.int16,
    "int32": jnp.int32,
}


@functools.partial(jax.jit, static_argnames=("bits", "block"))
def quantize_row_sr(
    row: jnp.ndarray,
    bits: int,
    sr_seed: jnp.ndarray,
    row_index: jnp.ndarray,
    block: int = 0,
):
    """Client-side uplink quantization of one flat packed row.

    Stochastic rounding driven by the OTA data plane's positional dither
    (``kernels.ota_fused.sr_dither`` over ``(sr_seed, row_index, pos)``) —
    the identical uniforms the in-kernel quantizer and the per-tree oracle
    draw, so a client quantizing at the edge produces bit-for-bit the
    symbols the fused f32 path would have produced on the server. Returns
    (q, scale): q int8 for bits <= 8, int16/int32 up to 16/31 bits, the
    f32 row unchanged (scale 1) for bits >= 32 — and for bits <= 1,
    whose symmetric grid is empty (qmax = 0): those pass through
    unquantized, mirroring the fused kernel's qmax == 0 passthrough
    instead of dividing by zero. Zero padding quantizes to exact
    integer 0 (frac = 0 and the dither is strictly < 1), so packed rows
    keep the exact-zero pad region the aggregate norm relies on.

    ``block`` > 0 switches to **blockwise scales** (DESIGN.md §6): the
    row is split into ceil(M / block)-many runs of ``block`` symbols
    (last one ragged — the zero pad region simply falls into it), each
    with its own symmetric amax-derived scale, and ``scale`` comes back
    as an (n_blocks,) f32 vector. One outlier leaf then inflates only
    its own block's int grid instead of the whole row's. ``block`` <= 0
    or >= M is the per-row degenerate case: scale stays the () scalar of
    the PR-2 wire format (old rows parse unchanged) and the symbols are
    bit-identical to the in-pass quantizer. The dither is positional, so
    the block structure never perturbs the rounding stream.
    """
    from repro.core.packing import wire_kind
    from repro.kernels.ota_fused import sr_dither

    row = jnp.asarray(row).astype(jnp.float32)
    kind = wire_kind(bits)
    if kind == "float32":
        return row, jnp.ones((), jnp.float32)
    qmax = jnp.exp2(jnp.float32(bits - 1)) - 1.0  # == qrange(bits), f32
    M = row.shape[0]
    if 0 < block < M:
        n_blocks = -(-M // block)
        pad = n_blocks * block - M
        padded = jnp.pad(row, (0, pad)) if pad else row
        amax = jnp.max(jnp.abs(padded.reshape(n_blocks, block)), axis=1)
        scale = jnp.maximum(amax, 1e-12) / qmax        # (n_blocks,)
        scale_cols = jnp.repeat(scale, block)[:M]
    else:
        amax = jnp.max(jnp.abs(row))
        scale = jnp.maximum(amax, 1e-12) / qmax        # ()
        scale_cols = scale
    pos = jnp.arange(M, dtype=jnp.uint32)
    u = sr_dither(
        jnp.asarray(sr_seed, jnp.uint32), jnp.asarray(row_index, jnp.uint32), pos
    )
    scaled = row / scale_cols
    floor = jnp.floor(scaled)
    q = floor + (u < (scaled - floor)).astype(jnp.float32)
    q = jnp.clip(q, -qmax, qmax)
    return q.astype(_STORAGE_DTYPE[kind]), scale


# ---------------------------------------------------------------------------
# quantized optimizer/server state (DESIGN.md §13)
# ---------------------------------------------------------------------------

# Symbols per scale for resident quantized state. Matches the wire/arena
# default (``packing.QUANT_BLOCK`` / retrieval's int8 storage class): one
# f32 scale per 256 values costs 1/64 of the int8 payload.
STATE_BLOCK = 256


@functools.partial(jax.jit, static_argnames=("bits", "block"))
def quantize_state(x: jnp.ndarray, *, bits: int = 8, block: int = STATE_BLOCK):
    """Blockwise symmetric quantization of one resident state tensor.

    The storage class for server-side optimizer state (second moments,
    EMAs): ``x`` is flattened, split into ``block``-value runs (last one
    ragged), and each run is rounded-to-nearest onto the shared
    amax/qmax grid — ``scale = max(amax, 1e-12) / qmax``, the same grid
    ``quantize_row_sr`` and the retrieval arena use. Rounding is
    deterministic (no dither): state is private to the server and
    re-quantized every step, so the cross-client unbiasedness argument
    that makes the *wire* stochastic does not apply here.

    Returns (q, scale): q int8 in ``x``'s shape, scale (n_blocks,) f32
    over the flattened order. ``block`` <= 0 or >= size degenerates to
    one per-tensor scale (n_blocks = 1).
    """
    assert 2 <= bits <= 8, "int8 storage class: 2..8 bits"
    x = jnp.asarray(x)
    shape = x.shape
    flat = x.astype(jnp.float32).reshape(-1)
    M = flat.shape[0]
    qmax = jnp.float32(qrange(bits))
    if 0 < block < M:
        n_blocks = -(-M // block)
        pad = n_blocks * block - M
        padded = jnp.pad(flat, (0, pad)) if pad else flat
        amax = jnp.max(jnp.abs(padded.reshape(n_blocks, block)), axis=1)
        scale = jnp.maximum(amax, 1e-12) / qmax  # (n_blocks,)
        cols = jnp.repeat(scale, block)[:M]
    else:
        amax = jnp.max(jnp.abs(flat))
        scale = (jnp.maximum(amax, 1e-12) / qmax).reshape(1)
        cols = scale[0]
    q = jnp.clip(jnp.round(flat / cols), -qmax, qmax).astype(jnp.int8)
    return q.reshape(shape), scale


@functools.partial(jax.jit, static_argnames=("block",))
def dequantize_state(
    q: jnp.ndarray, scale: jnp.ndarray, *, block: int = STATE_BLOCK
) -> jnp.ndarray:
    """Inverse of ``quantize_state``: q * scale[block], back in q's shape."""
    shape = q.shape
    flat = q.reshape(-1).astype(jnp.float32)
    scale = jnp.atleast_1d(jnp.asarray(scale, jnp.float32))
    if scale.shape[0] > 1:
        bid = jnp.arange(flat.shape[0], dtype=jnp.int32) // block
        flat = flat * jnp.take(scale, bid, mode="clip")
    else:
        flat = flat * scale[0]
    return flat.reshape(shape)


@jax.custom_vjp
def ste_fake_quant(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Fake-quant with straight-through gradients (for QAT local training)."""
    return fake_quant(x, bits)


def _ste_fwd(x, bits):
    return fake_quant(x, bits), None


def _ste_bwd(_, g):
    return (g, None)


ste_fake_quant.defvjp(_ste_fwd, _ste_bwd)


# ---------------------------------------------------------------------------
# pytree-level helpers (client model / update quantization)
# ---------------------------------------------------------------------------


def quantize_tree(
    tree: Pytree, bits: int, *, key: Optional[jax.Array] = None
) -> Tuple[Pytree, Pytree]:
    """Quantize every leaf per-tensor. Returns (q_tree, scale_tree)."""
    leaves, treedef = jax.tree.flatten(tree)
    if key is not None:
        keys = list(jax.random.split(key, len(leaves)))
    else:
        keys = [None] * len(leaves)
    qs, scales = [], []
    for leaf, k in zip(leaves, keys):
        q, s = quantize(leaf, bits, key=k)
        qs.append(q)
        scales.append(s)
    return jax.tree.unflatten(treedef, qs), jax.tree.unflatten(treedef, scales)


def dequantize_tree(q_tree: Pytree, scale_tree: Pytree, bits: int) -> Pytree:
    return jax.tree.map(lambda q, s: dequantize(q, s, bits), q_tree, scale_tree)


def fake_quant_tree(
    tree: Pytree, bits: int, *, key: Optional[jax.Array] = None
) -> Pytree:
    if bits >= 32:
        return tree
    leaves, treedef = jax.tree.flatten(tree)
    if key is not None:
        keys = list(jax.random.split(key, len(leaves)))
    else:
        keys = [None] * len(leaves)
    out = [fake_quant(leaf, bits, key=k) for leaf, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, out)


def quant_error(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    """RMS relative quantization error (used by perf/accuracy priors)."""
    fq = fake_quant(x, bits)
    return jnp.sqrt(jnp.mean((x - fq) ** 2)) / jnp.maximum(
        jnp.sqrt(jnp.mean(x**2)), 1e-12
    )
