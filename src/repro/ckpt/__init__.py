from repro.ckpt.checkpoint import (CheckpointManager,  # noqa: F401
                                   load_checkpoint, save_checkpoint)
