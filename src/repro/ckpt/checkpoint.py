"""Pytree checkpointing: msgpack + zstd (or zlib), sharding-aware restore.

Format: a 1-byte codec flag followed by a compressed msgpack document
    {"tree": <structure with leaf placeholders>,
     "leaves": [{"dtype", "shape", "data"}...],
     "meta": {...user metadata...}}

The flag byte selects the codec: ``Z`` = zstandard, ``z`` = zlib.
``zstandard`` is an optional dependency — when the wheel is missing we
fall back to stdlib zlib, so checkpointing works on a bare environment.
Legacy flag-less files (raw zstd frames, magic ``0x28 B5 2F FD``) are
still readable when zstandard is installed.

bf16 leaves are stored natively as their raw 2-byte payload (uint16
view, tag ``bf16n``) — half the bytes of the legacy ``bf16`` tag, which
widened to f32 on disk; both tags restore to bf16 bit-for-bit. This is
what keeps quantized optimizer state (DESIGN.md §13: bf16 moments,
int8 second moments) compressed *through* the checkpoint, not just in
memory.

Restore accepts an optional target sharding tree: each leaf is
``jax.device_put`` to its NamedSharding so a multi-host/multi-device
restore lands sharded without a host-memory spike per device.
"""

from __future__ import annotations

import os
import tempfile
import zlib
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:
    import zstandard
except ImportError:  # optional wheel; zlib fallback below
    zstandard = None

Pytree = Any

_LEAF = "__leaf__"

_FLAG_ZSTD = b"Z"
_FLAG_ZLIB = b"z"
_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"  # legacy flag-less files


def _compress(doc: bytes, level: int) -> bytes:
    if zstandard is not None:
        return _FLAG_ZSTD + zstandard.ZstdCompressor(level=level).compress(doc)
    # zstd levels go to 22, zlib's cap is 9 — clamp rather than reject
    return _FLAG_ZLIB + zlib.compress(doc, min(level, 9))


def _decompress(blob: bytes) -> bytes:
    flag, payload = blob[:1], blob[1:]
    if flag == _FLAG_ZLIB:
        return zlib.decompress(payload)
    if flag == _FLAG_ZSTD or blob[:4] == _ZSTD_MAGIC:
        if zstandard is None:
            raise RuntimeError(
                "checkpoint was written with zstandard, which is not "
                "installed; pip install zstandard to read it"
            )
        data = payload if flag == _FLAG_ZSTD else blob
        return zstandard.ZstdDecompressor().decompress(data)
    raise ValueError(f"unrecognised checkpoint codec flag {flag!r}")


def _pack_tree(tree: Pytree):
    leaves, treedef = jax.tree.flatten(tree)
    structure = jax.tree.unflatten(treedef, list(range(len(leaves))))

    def encode_structure(node):
        if isinstance(node, dict):
            return {"t": "d", "v": {k: encode_structure(v) for k, v in node.items()}}
        if isinstance(node, (list, tuple)):
            return {
                "t": "l" if isinstance(node, list) else "t",
                "v": [encode_structure(v) for v in node],
            }
        return {"t": _LEAF, "v": int(node)}

    enc_leaves = []
    for leaf in leaves:
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            # native 2-byte storage: the uint16 bit pattern IS the bf16
            enc = {"dtype": "bf16n", "data": arr.view(np.uint16).tobytes()}
        else:
            enc = {"dtype": arr.dtype.str, "data": arr.tobytes()}
        enc["shape"] = list(arr.shape)
        enc_leaves.append(enc)
    return encode_structure(structure), enc_leaves


def _unpack_tree(structure, leaves):
    def decode(node):
        t = node["t"]
        if t == "d":
            return {k: decode(v) for k, v in node["v"].items()}
        if t in ("l", "t"):
            seq = [decode(v) for v in node["v"]]
            return seq if t == "l" else tuple(seq)
        enc = leaves[node["v"]]
        if enc["dtype"] == "bf16n":
            arr = np.frombuffer(enc["data"], np.uint16).reshape(enc["shape"])
            return jnp.asarray(arr.view(np.dtype(jnp.bfloat16)))
        if enc["dtype"] == "bf16":  # legacy: bf16 widened to f32 bytes
            arr = np.frombuffer(enc["data"], np.float32).reshape(enc["shape"])
            return jnp.asarray(arr, jnp.bfloat16)
        arr = np.frombuffer(enc["data"], np.dtype(enc["dtype"]))
        return arr.reshape(enc["shape"])

    return decode(structure)


def save_checkpoint(
    path: str,
    tree: Pytree,
    meta: Optional[Dict[str, Any]] = None,
    level: int = 3,
) -> None:
    structure, leaves = _pack_tree(tree)
    doc = msgpack.packb(
        {"tree": structure, "leaves": leaves, "meta": meta or {}},
        use_bin_type=True,
    )
    comp = _compress(doc, level)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    # atomic write
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(comp)
        os.replace(tmp, path)
    except BaseException:
        os.unlink(tmp)
        raise


def load_checkpoint(path: str, shardings: Optional[Pytree] = None):
    """Returns (tree, meta). With ``shardings``, leaves are device_put
    to the given NamedShardings as they are decoded."""
    with open(path, "rb") as f:
        doc = msgpack.unpackb(_decompress(f.read()), raw=False)
    tree = _unpack_tree(doc["tree"], doc["leaves"])
    if shardings is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(jnp.asarray(x), s), tree, shardings
        )
    else:
        tree = jax.tree.map(jnp.asarray, tree)
    return tree, doc["meta"]


class CheckpointManager:
    """Rolling checkpoints: keep the latest ``keep`` files per run dir."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def path(self, step: int) -> str:
        return os.path.join(self.directory, f"ckpt_{step:08d}.msgpack.zst")

    def save(self, step: int, tree: Pytree, meta: Optional[Dict] = None):
        save_checkpoint(self.path(step), tree, dict(meta or {}, step=step))
        self._gc()

    def latest_step(self) -> Optional[int]:
        steps = sorted(self._steps())
        return steps[-1] if steps else None

    def restore_latest(self, shardings: Optional[Pytree] = None):
        step = self.latest_step()
        if step is None:
            return None, None
        return load_checkpoint(self.path(step), shardings)

    def _steps(self):
        out = []
        for f in os.listdir(self.directory):
            if f.startswith("ckpt_") and f.endswith(".msgpack.zst"):
                out.append(int(f[5:13]))
        return out

    def _gc(self):
        steps = sorted(self._steps())
        for s in steps[: -self.keep]:
            os.unlink(self.path(s))
