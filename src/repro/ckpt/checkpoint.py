"""Pytree checkpointing: msgpack + zstd, sharding-aware restore.

Format: a zstd-compressed msgpack document
    {"tree": <structure with leaf placeholders>,
     "leaves": [{"dtype", "shape", "data"}...],
     "meta": {...user metadata...}}

Restore accepts an optional target sharding tree: each leaf is
``jax.device_put`` to its NamedSharding so a multi-host/multi-device
restore lands sharded without a host-memory spike per device.
"""
from __future__ import annotations

import os
import tempfile
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np
import zstandard

Pytree = Any

_LEAF = "__leaf__"


def _pack_tree(tree: Pytree):
    leaves, treedef = jax.tree.flatten(tree)
    structure = jax.tree.unflatten(treedef, list(range(len(leaves))))

    def encode_structure(node):
        if isinstance(node, dict):
            return {"t": "d", "v": {k: encode_structure(v) for k, v in node.items()}}
        if isinstance(node, (list, tuple)):
            return {"t": "l" if isinstance(node, list) else "t",
                    "v": [encode_structure(v) for v in node]}
        return {"t": _LEAF, "v": int(node)}

    enc_leaves = []
    for leaf in leaves:
        arr = np.asarray(leaf)
        enc_leaves.append({
            "dtype": arr.dtype.str if arr.dtype != jnp.bfloat16 else "bf16",
            "shape": list(arr.shape),
            "data": (arr.astype(np.float32).tobytes()
                     if arr.dtype == jnp.bfloat16 else arr.tobytes()),
        })
    return encode_structure(structure), enc_leaves


def _unpack_tree(structure, leaves):
    def decode(node):
        t = node["t"]
        if t == "d":
            return {k: decode(v) for k, v in node["v"].items()}
        if t in ("l", "t"):
            seq = [decode(v) for v in node["v"]]
            return seq if t == "l" else tuple(seq)
        enc = leaves[node["v"]]
        if enc["dtype"] == "bf16":
            arr = np.frombuffer(enc["data"], np.float32).reshape(enc["shape"])
            return jnp.asarray(arr, jnp.bfloat16)
        arr = np.frombuffer(enc["data"], np.dtype(enc["dtype"]))
        return arr.reshape(enc["shape"])

    return decode(structure)


def save_checkpoint(path: str, tree: Pytree,
                    meta: Optional[Dict[str, Any]] = None,
                    level: int = 3) -> None:
    structure, leaves = _pack_tree(tree)
    doc = msgpack.packb({"tree": structure, "leaves": leaves,
                         "meta": meta or {}}, use_bin_type=True)
    comp = zstandard.ZstdCompressor(level=level).compress(doc)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    # atomic write
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(comp)
        os.replace(tmp, path)
    except BaseException:
        os.unlink(tmp)
        raise


def load_checkpoint(path: str, shardings: Optional[Pytree] = None):
    """Returns (tree, meta). With ``shardings``, leaves are device_put
    to the given NamedShardings as they are decoded."""
    with open(path, "rb") as f:
        doc = msgpack.unpackb(zstandard.ZstdDecompressor().decompress(f.read()),
                              raw=False)
    tree = _unpack_tree(doc["tree"], doc["leaves"])
    if shardings is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(jnp.asarray(x), s), tree, shardings)
    else:
        tree = jax.tree.map(jnp.asarray, tree)
    return tree, doc["meta"]


class CheckpointManager:
    """Rolling checkpoints: keep the latest ``keep`` files per run dir."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def path(self, step: int) -> str:
        return os.path.join(self.directory, f"ckpt_{step:08d}.msgpack.zst")

    def save(self, step: int, tree: Pytree, meta: Optional[Dict] = None):
        save_checkpoint(self.path(step), tree, dict(meta or {}, step=step))
        self._gc()

    def latest_step(self) -> Optional[int]:
        steps = sorted(self._steps())
        return steps[-1] if steps else None

    def restore_latest(self, shardings: Optional[Pytree] = None):
        step = self.latest_step()
        if step is None:
            return None, None
        return load_checkpoint(self.path(step), shardings)

    def _steps(self):
        out = []
        for f in os.listdir(self.directory):
            if f.startswith("ckpt_") and f.endswith(".msgpack.zst"):
                out.append(int(f[5:13]))
        return out

    def _gc(self):
        steps = sorted(self._steps())
        for s in steps[: -self.keep]:
            os.unlink(self.path(s))
