"""Host-side span tracer with a Chrome/Perfetto ``trace_event`` exporter
(DESIGN.md §14).

One process-global :class:`Tracer` records *spans* — named, nested
host-time intervals — around the FL round pipeline (``fl/server.py``:
``plan -> channel_sample -> client_train -> uplink_encode -> fold ->
finalize -> optimizer -> broadcast_encode -> feedback``), the retrieval
query path (``retrieval/engine.py``), and the serving engine's
prefill/decode steps (``serve/engine.py``). Spans measure *host*
wall-clock: jax dispatch is asynchronous, so a span around a jitted call
times dispatch (plus any blocking device transfer inside), not device
execution — the right clock for finding host-side stalls, retrace storms,
and stage imbalance in the round loop.

Design constraints:

- **Near-zero overhead when disabled** (the default): ``span()`` is one
  global attribute check returning a shared no-op context-manager
  singleton — no allocation, no clock read. The disabled path leaves
  every instrumented computation byte-identical to the uninstrumented
  program (spans only observe; ``tests/test_obs.py`` pins this).
- **Monotonic clocks**: timestamps are ``time.perf_counter_ns`` relative
  to the tracer's epoch, exported in microseconds (the ``trace_event``
  unit).
- **Nested spans**: a per-thread depth counter tracks nesting; events
  are appended at span *exit*, so children complete before parents and
  the Perfetto ``ph: "X"`` (complete-event) nesting is reconstructed
  from ts/dur containment on one track per thread.

Enable either through the context managers (``with trace.enabled(): ...``
— the bench/test idiom, restores the previous state) or imperatively
(``get_tracer().enable()``). ``export_perfetto`` emits the Chrome
``trace_event`` JSON (``{"traceEvents": [{"ph": "X", "ts", "dur",
"name", ...}]}``) that chrome://tracing and ui.perfetto.dev load
directly.
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
import json
import os
import threading
import time
from typing import Any, Dict, Iterator, List, Optional, Set


@dataclasses.dataclass(frozen=True)
class SpanEvent:
    """One completed span: [ts_us, ts_us + dur_us] on thread ``tid``."""

    name: str
    ts_us: float  # start, µs since the tracer epoch (monotonic)
    dur_us: float
    depth: int  # nesting depth at entry (0 = top level on its thread)
    tid: int
    args: Optional[Dict[str, Any]] = None


class _NullSpan:
    """Shared no-op span: what ``span()`` returns while disabled."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc: Any) -> bool:
        return False


NULL_SPAN = _NullSpan()


class _Span:
    """A live span (context manager); records itself on exit."""

    __slots__ = ("_tracer", "_name", "_args", "_t0", "_depth")

    def __init__(self, tracer: "Tracer", name: str, args: Optional[Dict[str, Any]]):
        self._tracer = tracer
        self._name = name
        self._args = args

    def __enter__(self) -> "_Span":
        local = self._tracer._local
        self._depth = getattr(local, "depth", 0)
        local.depth = self._depth + 1
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc: Any) -> bool:
        t1 = time.perf_counter_ns()
        tracer = self._tracer
        tracer._local.depth = self._depth
        if tracer.enabled:  # disabled mid-span: drop, don't record
            tracer._events.append(
                SpanEvent(
                    name=self._name,
                    ts_us=(self._t0 - tracer._epoch_ns) / 1e3,
                    dur_us=(t1 - self._t0) / 1e3,
                    depth=self._depth,
                    tid=threading.get_ident(),
                    args=self._args or None,
                )
            )
        return False


class Tracer:
    """Process-local span recorder. Disabled (and empty) by default."""

    def __init__(self) -> None:
        self.enabled = False
        self._events: List[SpanEvent] = []
        self._epoch_ns = time.perf_counter_ns()
        self._local = threading.local()

    # -- lifecycle ------------------------------------------------------
    def enable(self) -> "Tracer":
        self.enabled = True
        return self

    def disable(self) -> "Tracer":
        self.enabled = False
        return self

    def reset(self) -> "Tracer":
        """Drop recorded events and restart the epoch clock."""
        self._events = []
        self._epoch_ns = time.perf_counter_ns()
        return self

    # -- recording ------------------------------------------------------
    def span(self, name: str, **args: Any) -> Any:
        """Context manager timing one named span (no-op when disabled)."""
        if not self.enabled:
            return NULL_SPAN
        return _Span(self, name, args if args else None)

    # -- inspection -----------------------------------------------------
    @property
    def events(self) -> List[SpanEvent]:
        return list(self._events)

    def span_names(self) -> Set[str]:
        return {e.name for e in self._events}

    def summary(self) -> Dict[str, Dict[str, float]]:
        """Per-span-name {count, total_us, max_us} rollup."""
        out: Dict[str, Dict[str, float]] = {}
        for e in self._events:
            s = out.setdefault(e.name, {"count": 0, "total_us": 0.0, "max_us": 0.0})
            s["count"] += 1
            s["total_us"] += e.dur_us
            s["max_us"] = max(s["max_us"], e.dur_us)
        return out

    # -- export ---------------------------------------------------------
    def export_perfetto(self, path: Optional[str] = None) -> str:
        """Chrome/Perfetto ``trace_event`` JSON for the recorded spans.

        Complete events (``ph: "X"``) carry ``ts``/``dur`` in µs; one
        ``tid`` per recording thread reconstructs nesting by interval
        containment. Returns the JSON string; with ``path`` also writes
        it there (the CI telemetry artifact).
        """
        pid = os.getpid()
        events = [
            {
                "name": e.name,
                "ph": "X",
                "ts": e.ts_us,
                "dur": e.dur_us,
                "pid": pid,
                "tid": e.tid,
                "cat": "repro",
                **({"args": e.args} if e.args else {}),
            }
            for e in sorted(self._events, key=lambda e: e.ts_us)
        ]
        doc = {"traceEvents": events, "displayTimeUnit": "ms"}
        text = json.dumps(doc, indent=1, sort_keys=True)
        if path is not None:
            with open(path, "w") as f:
                f.write(text)
                f.write("\n")
        return text


_TRACER = Tracer()


def get_tracer() -> Tracer:
    """The process-global tracer instance."""
    return _TRACER


def is_enabled() -> bool:
    return _TRACER.enabled


def span(name: str, **args: Any) -> Any:
    """Module-level span helper — THE instrumentation call site idiom.

    ``with span("fold"): ...`` costs one attribute check and a shared
    singleton return when tracing is off.
    """
    t = _TRACER
    if not t.enabled:
        return NULL_SPAN
    return _Span(t, name, args if args else None)


def traced(name: Optional[str] = None):
    """Decorator form: time every call of the wrapped function."""

    def deco(fn):
        span_name = name or fn.__qualname__

        @functools.wraps(fn)
        def wrapper(*a: Any, **kw: Any):
            t = _TRACER
            if not t.enabled:
                return fn(*a, **kw)
            with _Span(t, span_name, None):
                return fn(*a, **kw)

        return wrapper

    return deco


@contextlib.contextmanager
def enabled(*, fresh: bool = True) -> Iterator[Tracer]:
    """Enable tracing for the block; restore the prior state after.

    ``fresh`` (default) resets recorded events and the epoch first, so
    the block's trace stands alone — the bench/test idiom.
    """
    t = _TRACER
    prev = t.enabled
    if fresh:
        t.reset()
    t.enable()
    try:
        yield t
    finally:
        t.enabled = prev


@contextlib.contextmanager
def disabled() -> Iterator[Tracer]:
    """Force tracing off for the block (overhead-comparison baseline)."""
    t = _TRACER
    prev = t.enabled
    t.disable()
    try:
        yield t
    finally:
        t.enabled = prev
