"""Telemetry export: JSONL event sink, summary rollup, and the shared
benchmark-report writer (DESIGN.md §14).

Three consumers, one format each:

- :class:`JsonlSink` — append-only JSON-lines event stream (one object
  per line: ``{"ts": <unix seconds>, "kind": ..., "name": ..., ...}``).
  ``dump_telemetry`` writes the current metrics registry + span summary
  through it — the machine-readable round ledger CI uploads as an
  artifact next to the Perfetto trace.
- :func:`summary` — one nested dict snapshot (metrics + per-span
  rollup), the payload benches embed in their JSON reports.
- :func:`write_bench_report` / :func:`write_all_bench_reports` — the
  single ``BENCH_<name>.json`` writer every benchmark shares.
  ``benchmarks/run.py --json`` used to copy-paste the open/dump/print
  loop per bench; now each bench only supplies a ``json_report()``
  payload and registers in :data:`BENCH_REPORTS`.
"""

from __future__ import annotations

import importlib
import json
import time
from typing import Any, Dict, Iterable, List, Optional, Sequence

from repro.obs import metrics as _metrics
from repro.obs import trace as _trace


class JsonlSink:
    """Append-only JSONL event sink (one JSON object per line)."""

    def __init__(self, path: str, *, clock=time.time):
        self.path = path
        self._clock = clock
        self._f = open(path, "a")

    def emit(self, kind: str, name: str, **fields: Any) -> None:
        rec = {"ts": self._clock(), "kind": kind, "name": name}
        rec.update(fields)
        self._f.write(json.dumps(rec, sort_keys=True))
        self._f.write("\n")

    def close(self) -> None:
        self._f.close()

    def __enter__(self) -> "JsonlSink":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()


def summary(
    *,
    registry: Optional[_metrics.Registry] = None,
    tracer: Optional[_trace.Tracer] = None,
) -> Dict[str, Any]:
    """One snapshot dict: metrics registry + per-span-name rollup."""
    registry = registry or _metrics.REGISTRY
    tracer = tracer or _trace.get_tracer()
    return {"metrics": registry.snapshot(), "spans": tracer.summary()}


def dump_telemetry(
    jsonl_path: str,
    trace_path: Optional[str] = None,
    *,
    registry: Optional[_metrics.Registry] = None,
    tracer: Optional[_trace.Tracer] = None,
) -> Dict[str, Any]:
    """Flush current telemetry to disk; returns the summary written.

    Each metric series becomes one JSONL event (``kind`` = counter /
    gauge / histogram, ``value`` the float or the {count,total,min,max}
    dict), each span name one ``kind: "span"`` rollup line. With
    ``trace_path`` the full Perfetto ``trace_event`` JSON is written
    too (``Tracer.export_perfetto``).
    """
    s = summary(registry=registry, tracer=tracer)
    with JsonlSink(jsonl_path) as sink:
        for kind in ("counters", "gauges", "histograms"):
            for name, value in sorted(s["metrics"][kind].items()):
                sink.emit(kind[:-1], name, value=value)
        for name, roll in sorted(s["spans"].items()):
            sink.emit("span", name, **roll)
    if trace_path is not None:
        (tracer or _trace.get_tracer()).export_perfetto(trace_path)
    return s


# ---------------------------------------------------------------------------
# shared benchmark report path (benchmarks/run.py --json)
# ---------------------------------------------------------------------------

# every bench exposing json_report(), in run order. The module paths are
# imported lazily (write_all_bench_reports) so repro.obs never imports
# the benchmarks package at module load.
BENCH_REPORTS: Sequence[str] = (
    "aggregation",
    "retrieval",
    "streaming",
    "channel",
    "mesh",
    "satisfaction",
    "strategies",
    "obs",
)


def write_bench_report(
    name: str, payload: Dict[str, Any], directory: str = "."
) -> str:
    """Write one ``BENCH_<name>.json`` (sorted, indented, newline-
    terminated — the established report shape) and return its path."""
    path = f"{directory}/BENCH_{name}.json"
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {path}")
    return path


def write_all_bench_reports(
    names: Optional[Iterable[str]] = None, directory: str = "."
) -> List[str]:
    """Import each bench in ``names`` (default: all of BENCH_REPORTS),
    call its ``json_report()``, and write the shared report file."""
    paths = []
    for name in names if names is not None else BENCH_REPORTS:
        mod = importlib.import_module(f"benchmarks.bench_{name}")
        paths.append(write_bench_report(name, mod.json_report(), directory))
    return paths
