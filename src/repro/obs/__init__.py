"""Unified telemetry layer (DESIGN.md §14): span tracing, a process-
local metrics registry, and JSONL / Perfetto exporters shared by the FL
data plane (``core/ota.py``, ``core/wire.py``), the control plane
(``fl/server.py``, ``retrieval/engine.py``), and the serving engine.

The instrumentation idiom::

    from repro import obs

    with obs.span("fold", rows=k):
        ...
    obs.metrics.inc("ota.uplink_bytes", nbytes)

Tracing is off by default and ``obs.span`` is a near-no-op then;
``with obs.enabled(): ...`` turns one block's telemetry on,
``obs.disabled()`` forces it off (the overhead baseline the
``benchmarks/bench_obs.py --smoke`` bar compares against). Metrics are
always-on host arithmetic. Importing this package installs the jax
trace/compile hook feeding the ``jax.retraces`` counter.
"""

from repro.obs import export, metrics, trace
from repro.obs.trace import (
    NULL_SPAN,
    SpanEvent,
    Tracer,
    disabled,
    enabled,
    get_tracer,
    is_enabled,
    span,
    traced,
)

metrics.install_jax_hooks()

__all__ = [
    "NULL_SPAN",
    "SpanEvent",
    "Tracer",
    "disabled",
    "enabled",
    "export",
    "get_tracer",
    "is_enabled",
    "metrics",
    "span",
    "trace",
    "traced",
]
