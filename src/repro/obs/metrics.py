"""Process-local metrics registry: counters, gauges, histograms
(DESIGN.md §14).

One module-global :class:`Registry` is the single place the FL data and
control planes publish numbers into — ``RoundLog``/``StreamRoundLog``
(round wire legs, loss, satisfaction), ``ota.AggregateInfo`` (uplink
bytes, truncation, misalignment, noise), the wire codec (quantization
MSE proxy), the retrieval engine (query counts), the serving engine
(token throughput), and a ``jax.monitoring`` hook counting jit
traces/compiles — instead of each subsystem growing ad-hoc report
fields. Reads are ``snapshot()`` (a plain nested dict, the JSONL/export
payload) or ``get(name, **labels)`` for one value.

Metric taxonomy:

- **counter** — monotonically accumulating float (``inc``): byte
  ledgers, row/query/event counts, jit retraces.
- **gauge** — last-write-wins float (``set_gauge``): per-round rates
  (truncation rate, misalignment), losses.
- **histogram** — running {count, total, min, max} summary
  (``observe``): staleness discounts, per-row quantization MSE proxy.
  (No buckets: the consumers are regression diffs and dashboards fed
  from JSONL, not quantile queries.)

Labels: optional keyword labels qualify a series
(``inc("ota.rows", 3, kind="int4")`` keys the series
``ota.rows{kind=int4}``). The un-labelled and labelled series are
distinct.

Publishing is host-arithmetic only (dict update under a lock) and
always on — the values are already host floats where the calls sit.
Device-derived extras (the wire MSE proxy) are computed by their call
sites only while the span tracer is enabled, so the tracer's
"near-zero overhead when disabled" contract covers the registry too.

The jax hook (``install_jax_hooks``, installed on first import of
``repro.obs``) listens on ``jax.monitoring`` duration events:
``jax.retraces`` counts jaxpr traces (one per jit cache *miss* — a
cached dispatch emits nothing, so a flat retrace counter across rounds
IS the cache-hit signal), ``jax.compiles``/``jax.compile_seconds``
count backend (XLA) compilations and their cost. The retrace-storm
regression guard in ``tests/test_obs.py`` reads ``jax.retraces``.
"""

from __future__ import annotations

import threading
from typing import Any, Dict, Optional, Tuple

_LabelKey = Tuple[Tuple[str, str], ...]


def _series(name: str, labels: Dict[str, Any]) -> str:
    """Canonical series name: ``name`` or ``name{k=v,...}`` (sorted)."""
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class Registry:
    """Thread-safe process-local metrics store."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._counters: Dict[str, float] = {}
        self._gauges: Dict[str, float] = {}
        self._hists: Dict[str, Dict[str, float]] = {}

    # -- writes ---------------------------------------------------------
    def inc(self, name: str, value: float = 1.0, **labels: Any) -> None:
        key = _series(name, labels)
        with self._lock:
            self._counters[key] = self._counters.get(key, 0.0) + value

    def set_gauge(self, name: str, value: float, **labels: Any) -> None:
        key = _series(name, labels)
        with self._lock:
            self._gauges[key] = float(value)

    def observe(self, name: str, value: float, **labels: Any) -> None:
        key = _series(name, labels)
        v = float(value)
        with self._lock:
            h = self._hists.get(key)
            if h is None:
                self._hists[key] = {"count": 1, "total": v, "min": v, "max": v}
            else:
                h["count"] += 1
                h["total"] += v
                h["min"] = min(h["min"], v)
                h["max"] = max(h["max"], v)

    # -- reads ----------------------------------------------------------
    def get(self, name: str, default: Optional[float] = None, **labels: Any):
        """One series' value: counter/gauge float, histogram dict."""
        key = _series(name, labels)
        with self._lock:
            if key in self._counters:
                return self._counters[key]
            if key in self._gauges:
                return self._gauges[key]
            if key in self._hists:
                return dict(self._hists[key])
        return default

    def snapshot(self) -> Dict[str, Dict[str, Any]]:
        """Plain-dict view: {"counters": {...}, "gauges": {...},
        "histograms": {series: {count,total,min,max}}}."""
        with self._lock:
            return {
                "counters": dict(self._counters),
                "gauges": dict(self._gauges),
                "histograms": {k: dict(v) for k, v in self._hists.items()},
            }

    def reset(self) -> None:
        """Zero every series (fresh bench/test scope)."""
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._hists.clear()


REGISTRY = Registry()

# module-level aliases: the instrumentation call-site idiom
# (``metrics.inc("fl.uplink_bytes", n)``)
inc = REGISTRY.inc
set_gauge = REGISTRY.set_gauge
observe = REGISTRY.observe
get = REGISTRY.get
snapshot = REGISTRY.snapshot
reset = REGISTRY.reset


# ---------------------------------------------------------------------------
# jax lower/compile hook: jit retrace + XLA compile accounting
# ---------------------------------------------------------------------------

_TRACE_EVENT = "/jax/core/compile/jaxpr_trace_duration"
_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"

_hooks_installed = False
_hooks_lock = threading.Lock()


def _on_duration_event(event: str, duration_secs: float, **kw: Any) -> None:
    if event == _TRACE_EVENT:
        REGISTRY.inc("jax.retraces")
    elif event == _COMPILE_EVENT:
        REGISTRY.inc("jax.compiles")
        REGISTRY.inc("jax.compile_seconds", duration_secs)


def install_jax_hooks() -> None:
    """Register the ``jax.monitoring`` listener (idempotent).

    ``jax.monitoring`` has no per-listener unregister, so this installs
    exactly once per process; the listener writes into the module
    ``REGISTRY``, which ``reset()`` re-zeroes without re-registering.
    Importing ``repro.obs`` installs the hook — the listener itself
    fires only on trace/compile events, never on cached dispatches, so
    steady-state rounds pay nothing.
    """
    global _hooks_installed
    with _hooks_lock:
        if _hooks_installed:
            return
        import jax.monitoring

        jax.monitoring.register_event_duration_secs_listener(_on_duration_event)
        _hooks_installed = True
