"""Small shared utilities: mesh-aware sharding constraints, dtypes, trees."""
from __future__ import annotations

import contextlib

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


class _EmptyMesh:
    """Stands in for an empty abstract mesh on jax versions without one."""

    empty = True
    axis_names: tuple = ()
    axis_sizes: tuple = ()


_EMPTY_MESH = _EmptyMesh()


def get_abstract_mesh():
    """Version-compat ambient mesh lookup.

    ``jax.sharding.get_abstract_mesh`` only exists on jax >= 0.5; on 0.4.x
    the ambient mesh set by ``with mesh:`` lives in
    ``jax._src.mesh.thread_resources``. Both sources yield an object
    exposing ``.empty`` / ``.axis_names`` / ``.axis_sizes``, which is all
    callers here use; whichever holds a non-empty mesh wins (so both
    ``jax.set_mesh`` and the legacy ``with mesh:`` context activate the
    mesh-aware code paths). With neither set we report an empty mesh and
    callers degrade to their single-device behaviour.
    """
    abstract = None
    getter = getattr(jax.sharding, "get_abstract_mesh", None)
    if getter is not None:
        abstract = getter()
        if not abstract.empty:
            return abstract
    try:
        physical = __import__("jax._src.mesh", fromlist=["thread_resources"]
                              ).thread_resources.env.physical_mesh
        if not physical.empty:
            return physical
    except Exception:  # pragma: no cover - private API moved
        pass
    return abstract if abstract is not None else _EMPTY_MESH


# Meshes activated through use_mesh on jax versions where jax.set_mesh is
# a bare global setter (no context manager, no read-back API): we keep our
# own stack so nested/sequential use_mesh blocks restore the outer mesh on
# exit instead of leaking the inner one into the rest of the process.
_MESH_STACK: list = []


@contextlib.contextmanager
def use_mesh(mesh):
    """Activate ``mesh`` as the ambient mesh across jax versions.

    jax >= 0.5 spells this ``jax.set_mesh`` (a context manager in recent
    releases, a global setter before that); 0.4.x uses the ``with mesh:``
    Mesh context. ``get_abstract_mesh`` above reads back either form. On
    the global-setter variant the previous mesh is saved and restored on
    exit (``None`` — "no ambient mesh" — when this is the outermost
    block), so servers switching meshes mid-process don't leak the inner
    mesh past the ``with``.
    """
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is None:
        with mesh:
            yield
        return
    ctx = set_mesh(mesh)
    if hasattr(ctx, "__enter__"):
        with ctx:
            yield
        return
    _MESH_STACK.append(mesh)
    try:
        yield
    finally:
        _MESH_STACK.pop()
        if _MESH_STACK:
            set_mesh(_MESH_STACK[-1])
        else:
            try:
                set_mesh(None)
            except Exception:  # pragma: no cover - a jax.set_mesh that
                pass           # rejects None leaves no unset API;
                               # best-effort clear at the outermost level


def constrain(x: jnp.ndarray, spec: P) -> jnp.ndarray:
    """``with_sharding_constraint`` that no-ops when no mesh is active.

    Models call this on large intermediates (MoE dispatch buffers, SSM
    channel states). Under an active mesh the constraint binds; in
    single-device unit tests it silently disappears. Axis names
    not present in the active mesh are dropped from the spec.
    """
    mesh = get_abstract_mesh()
    if mesh.empty:
        return x
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))

    def filt(entry, dim):
        if entry is None:
            return None
        if isinstance(entry, (tuple, list)):
            kept = tuple(e for e in entry if e in sizes)
            total = 1
            for e in kept:
                total *= sizes[e]
            return kept if (kept and dim % total == 0) else None
        if entry not in sizes or dim % sizes[entry] != 0:
            return None
        return entry

    entries = list(spec) + [None] * (x.ndim - len(spec))
    new_spec = P(*(filt(e, d) for e, d in zip(entries, x.shape)))
    return jax.lax.with_sharding_constraint(x, new_spec)


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


def tree_size(tree) -> int:
    return sum(x.size for x in jax.tree.leaves(tree))


def tree_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def split_like(key, tree):
    """One PRNG key per leaf, mirroring the tree structure."""
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(treedef, list(keys))


def count_params(params) -> int:
    return tree_size(params)
