"""Small shared utilities: mesh-aware sharding constraints, dtypes, trees."""
from __future__ import annotations

from typing import Any, Iterable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def constrain(x: jnp.ndarray, spec: P) -> jnp.ndarray:
    """``with_sharding_constraint`` that no-ops when no mesh is active.

    Models call this on large intermediates (MoE dispatch buffers, SSM
    channel states). Under ``jax.set_mesh(production_mesh)`` the constraint
    binds; in single-device unit tests it silently disappears. Axis names
    not present in the active mesh are dropped from the spec.
    """
    mesh = jax.sharding.get_abstract_mesh()
    if mesh.empty:
        return x
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))

    def filt(entry, dim):
        if entry is None:
            return None
        if isinstance(entry, (tuple, list)):
            kept = tuple(e for e in entry if e in sizes)
            total = 1
            for e in kept:
                total *= sizes[e]
            return kept if (kept and dim % total == 0) else None
        if entry not in sizes or dim % sizes[entry] != 0:
            return None
        return entry

    entries = list(spec) + [None] * (x.ndim - len(spec))
    new_spec = P(*(filt(e, d) for e, d in zip(entries, x.shape)))
    return jax.lax.with_sharding_constraint(x, new_spec)


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


def tree_size(tree) -> int:
    return sum(x.size for x in jax.tree.leaves(tree))


def tree_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def split_like(key, tree):
    """One PRNG key per leaf, mirroring the tree structure."""
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(treedef, list(keys))


def count_params(params) -> int:
    return tree_size(params)
