"""Pure-JAX optimizers (optax is not available in this environment).

An ``Optimizer`` is an (init, update) pair over pytrees:

    state = opt.init(params)
    updates, state = opt.update(grads, state, params, step)
    params = jax.tree.map(lambda p, u: p + u, params, updates)

All state lives in pytrees mirroring the params, so optimizer state
shards exactly like the parameters under pjit (ZeRO-style for free when
params are FSDP-sharded).

Quantized resident state (DESIGN.md §13): at million-user FL scale and
billion-parameter configs the f32 optimizer state is the dominant
server-resident memory, so ``momentum``/``adam``/``adamw`` accept
``quantize=True`` and then *store* their moments compressed — the first
moment in bf16 (sign-magnitude structure survives the 8-bit mantissa),
the second moment blockwise-int8 on ``core/quant``'s shared amax/qmax
grid (``quant.quantize_state``) — stored in the sqrt domain with a
half-step denominator floor; see ``_adam_impl``. Every ``update``
dequantizes to f32,
runs the standard math, and re-quantizes for storage, so the API and the
returned updates' dtypes are unchanged; ``state_nbytes`` reports the
resident footprint (bf16 m = 0.5x f32, int8 v ~ 0.27x).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

from repro.core import quant

Pytree = Any
Schedule = Callable[[jnp.ndarray], jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Pytree], Pytree]
    update: Callable[..., Tuple[Pytree, Pytree]]  # (grads, state, params, step)


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------


def constant_schedule(lr: float) -> Schedule:
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_schedule(lr: float, total_steps: int, min_frac: float = 0.1) -> Schedule:
    def fn(step):
        t = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        return lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))

    return fn


def linear_warmup_cosine(
    lr: float, warmup: int, total_steps: int, min_frac: float = 0.1
) -> Schedule:
    cos = cosine_schedule(lr, max(total_steps - warmup, 1), min_frac)

    def fn(step):
        warm = lr * jnp.minimum(step / max(warmup, 1), 1.0)
        return jnp.where(step < warmup, warm, cos(step - warmup))

    return fn


def _as_schedule(lr) -> Schedule:
    return lr if callable(lr) else constant_schedule(lr)


# ---------------------------------------------------------------------------
# gradient transforms
# ---------------------------------------------------------------------------


def clip_by_global_norm(grads: Pytree, max_norm: float) -> Tuple[Pytree, jnp.ndarray]:
    norm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    )
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads
    ), norm


# ---------------------------------------------------------------------------
# quantized-state storage helpers (DESIGN.md §13)
# ---------------------------------------------------------------------------


def state_nbytes(state: Pytree) -> int:
    """Resident bytes of an optimizer state pytree (leaf nbytes summed).

    The acceptance metric for quantized server state: quantized adam
    must come in <= 0.5x its f32 twin (bf16 m alone is exactly 0.5x;
    blockwise-int8 v is ~0.27x including scales).
    """
    return int(
        sum(l.size * jnp.dtype(l.dtype).itemsize for l in jax.tree.leaves(state))
    )


def _bf16_tree(tree: Pytree) -> Pytree:
    return jax.tree.map(lambda x: x.astype(jnp.bfloat16), tree)


def _f32_tree(tree: Pytree) -> Pytree:
    return jax.tree.map(lambda x: x.astype(jnp.float32), tree)


def _quantize_tree(tree: Pytree) -> Tuple[Pytree, Pytree]:
    """Per-leaf blockwise-int8 encode -> (q tree, scale tree)."""
    leaves, treedef = jax.tree.flatten(tree)
    pairs = [quant.quantize_state(l) for l in leaves]
    qs = jax.tree.unflatten(treedef, [q for q, _ in pairs])
    scales = jax.tree.unflatten(treedef, [s for _, s in pairs])
    return qs, scales


def _dequantize_tree(qs: Pytree, scales: Pytree) -> Pytree:
    return jax.tree.map(quant.dequantize_state, qs, scales)


def _grid_half_step(scale: jnp.ndarray, leaf: jnp.ndarray) -> jnp.ndarray:
    """Half the int8 grid step, broadcast to ``leaf``'s shape per block."""
    cols = jnp.repeat(jnp.atleast_1d(scale), quant.STATE_BLOCK)[: leaf.size]
    return (cols / 2).reshape(leaf.shape)


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------


def sgd(lr) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        return {}

    def update(grads, state, params, step):
        lr_t = sched(step)
        return jax.tree.map(lambda g: -lr_t * g.astype(jnp.float32), grads), state

    return Optimizer(init, update)


def momentum(lr, beta: float = 0.9, *, quantize: bool = False) -> Optimizer:
    """Heavy-ball momentum; ``quantize=True`` stores the velocity bf16."""
    sched = _as_schedule(lr)
    store_dtype = jnp.bfloat16 if quantize else jnp.float32

    def init(params):
        return {"m": jax.tree.map(lambda p: jnp.zeros_like(p, store_dtype), params)}

    def update(grads, state, params, step):
        m = jax.tree.map(
            lambda m_, g: beta * m_.astype(jnp.float32) + g.astype(jnp.float32),
            state["m"],
            grads,
        )
        lr_t = sched(step)
        store = _bf16_tree(m) if quantize else m
        return jax.tree.map(lambda m_: -lr_t * m_, m), {"m": store}

    return Optimizer(init, update)


def adam(
    lr,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    *,
    quantize: bool = False,
) -> Optimizer:
    return _adam_impl(lr, b1, b2, eps, weight_decay=0.0, quantize=quantize)


def adamw(
    lr,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.01,
    *,
    quantize: bool = False,
) -> Optimizer:
    return _adam_impl(lr, b1, b2, eps, weight_decay=weight_decay, quantize=quantize)


def _adam_impl(lr, b1, b2, eps, weight_decay, quantize: bool = False) -> Optimizer:
    """Adam/AdamW. ``quantize=True`` stores m bf16 and v blockwise-int8.

    The second moment is stored in the SQRT domain — ``v_q`` holds
    sqrt(v) on the int8 amax grid — and the update's denominator is
    floored at the grid's half-step. Both are load-bearing: a linear
    grid on v itself collapses small second moments in outlier-heavy
    blocks to integer 0, and a zero denominator turns the next step into
    mh/eps — a 10x-100x step explosion on exactly the coordinates that
    were quiet. sqrt compresses the block's dynamic range (error is
    linear in the *magnitude*, not the variance), and the half-step
    floor bounds the amplification of whatever still rounds to zero by
    the storage resolution itself. The moment recurrences and bias
    correction are the standard math on the dequantized f32 values.
    """
    sched = _as_schedule(lr)

    def init(params):
        def zeros(p):
            return jnp.zeros_like(p, jnp.float32)

        if not quantize:
            return {
                "m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
            }
        v_q, v_scale = _quantize_tree(jax.tree.map(zeros, params))
        return {
            "m": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.bfloat16), params),
            "v_q": v_q,
            "v_scale": v_scale,
        }

    def update(grads, state, params, step):
        t = step.astype(jnp.float32) + 1.0
        if quantize:
            m_prev = _f32_tree(state["m"])
            r_prev = _dequantize_tree(state["v_q"], state["v_scale"])
            v_prev = jax.tree.map(jnp.square, r_prev)
        else:
            m_prev, v_prev = state["m"], state["v"]
        m = jax.tree.map(
            lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32), m_prev, grads
        )
        v = jax.tree.map(
            lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            v_prev,
            grads,
        )
        mh = jax.tree.map(lambda m_: m_ / (1 - b1**t), m)
        lr_t = sched(step)
        bc2 = jnp.sqrt(1 - b2**t)
        if quantize:
            r = jax.tree.map(jnp.sqrt, v)
            v_q, v_scale = _quantize_tree(r)
            denom = jax.tree.map(
                lambda r_, s: jnp.maximum(r_, _grid_half_step(s, r_)) / bc2 + eps,
                r,
                v_scale,
            )
        else:
            # unchanged f32 ops: sqrt of the bias-corrected vh, then eps
            denom = jax.tree.map(lambda v_: jnp.sqrt(v_ / (1 - b2**t)) + eps, v)

        def upd(mh_, d_, p):
            u = -lr_t * mh_ / d_
            if weight_decay:
                u = u - lr_t * weight_decay * p.astype(jnp.float32)
            return u

        updates = jax.tree.map(upd, mh, denom, params)
        if not quantize:
            return updates, {"m": m, "v": v}
        return updates, {"m": _bf16_tree(m), "v_q": v_q, "v_scale": v_scale}

    return Optimizer(init, update)
