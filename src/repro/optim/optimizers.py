"""Pure-JAX optimizers (optax is not available in this environment).

An ``Optimizer`` is an (init, update) pair over pytrees:

    state = opt.init(params)
    updates, state = opt.update(grads, state, params, step)
    params = jax.tree.map(lambda p, u: p + u, params, updates)

All state lives in pytrees mirroring the params, so optimizer state
shards exactly like the parameters under pjit (ZeRO-style for free when
params are FSDP-sharded).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

Pytree = Any
Schedule = Callable[[jnp.ndarray], jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Pytree], Pytree]
    update: Callable[..., Tuple[Pytree, Pytree]]  # (grads, state, params, step)


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------


def constant_schedule(lr: float) -> Schedule:
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_schedule(lr: float, total_steps: int, min_frac: float = 0.1) -> Schedule:
    def fn(step):
        t = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        return lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))

    return fn


def linear_warmup_cosine(lr: float, warmup: int, total_steps: int,
                         min_frac: float = 0.1) -> Schedule:
    cos = cosine_schedule(lr, max(total_steps - warmup, 1), min_frac)

    def fn(step):
        warm = lr * jnp.minimum(step / max(warmup, 1), 1.0)
        return jnp.where(step < warmup, warm, cos(step - warmup))

    return fn


def _as_schedule(lr) -> Schedule:
    return lr if callable(lr) else constant_schedule(lr)


# ---------------------------------------------------------------------------
# gradient transforms
# ---------------------------------------------------------------------------


def clip_by_global_norm(grads: Pytree, max_norm: float) -> Tuple[Pytree, jnp.ndarray]:
    norm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------


def sgd(lr) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        return {}

    def update(grads, state, params, step):
        lr_t = sched(step)
        return jax.tree.map(lambda g: -lr_t * g.astype(jnp.float32), grads), state

    return Optimizer(init, update)


def momentum(lr, beta: float = 0.9) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        return {"m": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)}

    def update(grads, state, params, step):
        m = jax.tree.map(lambda m_, g: beta * m_ + g.astype(jnp.float32),
                         state["m"], grads)
        lr_t = sched(step)
        return jax.tree.map(lambda m_: -lr_t * m_, m), {"m": m}

    return Optimizer(init, update)


def adam(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8) -> Optimizer:
    return _adam_impl(lr, b1, b2, eps, weight_decay=0.0)


def adamw(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.01) -> Optimizer:
    return _adam_impl(lr, b1, b2, eps, weight_decay=weight_decay)


def _adam_impl(lr, b1, b2, eps, weight_decay) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        def zeros(p):
            return jnp.zeros_like(p, jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params)}

    def update(grads, state, params, step):
        t = step.astype(jnp.float32) + 1.0
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(
            lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["v"], grads)
        mh = jax.tree.map(lambda m_: m_ / (1 - b1 ** t), m)
        vh = jax.tree.map(lambda v_: v_ / (1 - b2 ** t), v)
        lr_t = sched(step)

        def upd(mh_, vh_, p):
            u = -lr_t * mh_ / (jnp.sqrt(vh_) + eps)
            if weight_decay:
                u = u - lr_t * weight_decay * p.astype(jnp.float32)
            return u

        return jax.tree.map(upd, mh, vh, params), {"m": m, "v": v}

    return Optimizer(init, update)
