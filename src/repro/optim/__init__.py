from repro.optim.optimizers import (  # noqa: F401
    Optimizer,
    adam,
    adamw,
    clip_by_global_norm,
    constant_schedule,
    cosine_schedule,
    linear_warmup_cosine,
    momentum,
    sgd,
    state_nbytes,
)
