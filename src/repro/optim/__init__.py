from repro.optim.optimizers import (  # noqa: F401
    Optimizer, adam, adamw, sgd, momentum, clip_by_global_norm,
    cosine_schedule, linear_warmup_cosine, constant_schedule,
)
