"""Continuous-batching inference: the serve engine under a request mix.

Submits a burst of variable-length requests against a 4-slot engine and
shows slot reuse / throughput — the runtime behaviour the decode_32k /
long_500k dry-run shapes correspond to at pod scale.

  PYTHONPATH=src python examples/continuous_batching.py
  PYTHONPATH=src python examples/continuous_batching.py --arch falcon-mamba-7b
"""
import argparse
import time

import numpy as np

from repro.configs import get_arch
from repro.serve import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    eng = ServeEngine(cfg, max_batch=args.max_batch, cache_len=128)
    rng = np.random.RandomState(args.seed)
    for i in range(args.requests):
        prompt = rng.randint(0, cfg.vocab_size,
                             size=int(rng.randint(4, 16))).astype(np.int32)
        eng.submit(Request(i, prompt,
                           max_new_tokens=int(rng.randint(8, 24))))

    t0 = time.time()
    done = eng.run_until_drained()
    dt = time.time() - t0
    s = eng.stats()
    print(f"arch={cfg.name} slots={args.max_batch} "
          f"requests={len(done)}/{args.requests}")
    print(f"decode steps: {s['decode_steps']}  tokens: {s['tokens']}  "
          f"tokens/step: {s['tokens_per_step']:.2f} "
          f"(continuous batching keeps slots busy)")
    print(f"wall: {dt:.1f}s  mean request latency: {s['mean_latency_s']:.2f}s")
    for r in done[:4]:
        print(f"  req {r.request_id}: prompt {len(r.prompt)} tok -> "
              f"generated {len(r.generated)} tok")


if __name__ == "__main__":
    main()
