"""End-to-end driver: the paper's federated smart-voice-assistant system.

Trains the DeepSpeech2-style ASR model federated over simulated clients
with RAG-based precision planning and mixed-precision OTA aggregation,
then evaluates per-category accuracy — the full §IV pipeline at a scale
that runs on this container's CPU.

  PYTHONPATH=src python examples/train_fl_voice.py --rounds 12
  PYTHONPATH=src python examples/train_fl_voice.py --planner unified
"""
import argparse
import time

from repro.configs.base import FLConfig
from repro.fl import FLServer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--clients", type=int, default=24)
    ap.add_argument("--per-round", type=int, default=6)
    ap.add_argument("--local-steps", type=int, default=3)
    ap.add_argument("--planner", default="rag",
                    choices=["rag", "unified", "rag_energy"])
    ap.add_argument("--strategy", default="fedavg",
                    choices=["fedavg", "class_equal", "majority_centric"])
    ap.add_argument("--channel", default="ideal", choices=["ideal", "fading"],
                    help="physical channel model (DESIGN.md §12)")
    ap.add_argument("--fade-threshold", type=float, default=0.1,
                    help="|h|^2 truncation threshold (fading channel)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = FLConfig(
        n_clients=args.clients, clients_per_round=args.per_round,
        n_rounds=args.rounds, local_steps=args.local_steps, local_batch=6,
        lr=2e-3, planner=args.planner, strategy=args.strategy,
        channel_model=args.channel, fade_threshold=args.fade_threshold,
        seed=args.seed)
    print(f"planner={args.planner} strategy={args.strategy} "
          f"channel={args.channel} "
          f"clients={args.clients} rounds={args.rounds}")
    srv = FLServer(cfg, shard_size=16)
    t0 = time.time()
    srv.run(args.rounds, verbose=True)
    print(f"\ntrained {args.rounds} rounds in {time.time()-t0:.0f}s")
    acc = srv.evaluate()
    print("per-category char accuracy:",
          {k: round(v, 3) for k, v in acc.items()})
    logs = srv.round_logs
    print(f"satisfaction {logs[0].mean_satisfaction:.3f} -> "
          f"{logs[-1].mean_satisfaction:.3f} | "
          f"rel energy {logs[-1].mean_energy:.3f} | "
          f"loss {logs[0].train_loss:.2f} -> {logs[-1].train_loss:.2f}")


if __name__ == "__main__":
    main()
