"""Quickstart: the RAG-based precision-planning pipeline in ~60 lines.

Walks one planning cycle for a handful of simulated clients:
interview -> contextual inference -> RAG retrieval -> Eqs (1)-(4) ->
multi-client slot packing -> quantized model + OTA aggregation.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import ota
from repro.core.profiling import (RAGPlanner, make_fleet, make_users,
                                  plan_round, satisfaction_score,
                                  true_performance)

N = 6
users = make_users(N, seed=42)
fleet = make_fleet(N, seed=42)
planner = RAGPlanner(seed=42)

print("=== interviews & precision decisions ===")
decisions = plan_round(planner.plan(users, fleet))
for d, u, s in zip(decisions, users, fleet):
    print(f"user {u.user_id} [{s.device_class:15s}] "
          f"true-w={{a:{u.weights['accuracy']:.2f},e:{u.weights['energy']:.2f},"
          f"l:{u.weights['latency']:.2f}}} ctx={u.location}/{u.interaction_time}")
    print(f"   said: {d.transcript[:90]!r}")
    print(f"   -> {d.bits}-bit (score est {d.score_est:+.3f}, "
          f"oracle sat {satisfaction_score(u, s, d.bits):+.3f})")

print("\n=== quantized updates -> OTA aggregation ===")
key = jax.random.key(0)
updates = [{"w": jax.random.normal(jax.random.fold_in(key, i), (1000,)) * 0.01}
           for i in range(N)]
bits = [d.bits for d in decisions]
agg, info = ota.ota_aggregate(key, updates, bits, [1.0] * N,
                              ota.OTAConfig(snr_db=20.0))
print(f"participating after fade truncation: {info['n_participating']}/{N}")
print(f"receiver noise std: {info['noise_std']:.2e}")
err = jnp.linalg.norm(agg["w"] - jnp.mean(
    jnp.stack([u["w"] for u in updates]), 0))
print(f"||OTA aggregate - ideal mean|| = {err:.3e} "
      f"(quantization + channel noise)")

print("\n=== feedback closes the loop ===")
for d, u, s in zip(decisions, users, fleet):
    planner.observe_feedback(u, s, d.bits,
                             satisfaction_score(u, s, d.bits),
                             true_performance(u, s, d.bits))
print(f"RAG DBs now hold {len(planner.cqf_db)} context records / "
      f"{len(planner.hqp_db)} hardware records; next round retrieves them.")
