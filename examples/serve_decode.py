"""Serving example: batched prefill + KV-cache decode on a pool arch.

Runs the reduced stablelm config end-to-end (prefill a prompt batch, then
step-decode with the ring-buffer cache), and demonstrates the sliding
window used for the long_500k shape.

  PYTHONPATH=src python examples/serve_decode.py
  PYTHONPATH=src python examples/serve_decode.py --arch zamba2-2.7b
"""
import argparse
import subprocess
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()
    # full attention cache
    subprocess.run([sys.executable, "-m", "repro.launch.serve",
                    "--arch", args.arch, "--reduced", "--batch", "2",
                    "--prompt-len", "24", "--gen", str(args.gen)],
                   check=True)
    # sliding-window cache (the long_500k decode mode, miniature)
    subprocess.run([sys.executable, "-m", "repro.launch.serve",
                    "--arch", args.arch, "--reduced", "--batch", "2",
                    "--prompt-len", "24", "--gen", str(args.gen),
                    "--window", "16"],
                   check=True)


if __name__ == "__main__":
    main()
