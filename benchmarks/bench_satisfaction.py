"""Paper Fig. 3 — satisfaction-score and relative-energy-cost distributions
under (a) unified-tier planning, (b) RAG-personalized planning, and
(c) RAG with server-side energy priority.

100 simulated clients (Gaussian sensitivities, Table-I contexts), several
feedback rounds so the RAG databases warm up, oracle-scored.
"""
from __future__ import annotations

import time
from collections import Counter
from typing import Dict, Tuple

import numpy as np

from repro.core.profiling import (RAGPlanner, UnifiedTierPlanner, make_fleet,
                                  make_users, plan_round, satisfaction_score,
                                  true_performance)


def run_planner(planner, users, fleet, rounds: int = 6):
    sats, energies, hist = [], [], Counter()
    for r in range(rounds):
        decisions = plan_round(planner.plan(users, fleet))
        for d, u, s in zip(decisions, users, fleet):
            sat = satisfaction_score(u, s, d.bits)
            perf = true_performance(u, s, d.bits)
            planner.observe_feedback(u, s, d.bits, sat, perf)
            if r == rounds - 1:
                sats.append(sat)
                energies.append(perf["energy"])
                hist[d.bits] += 1
    return np.array(sats), np.array(energies), dict(sorted(hist.items()))


def main(n_clients: int = 100, rounds: int = 6, seed: int = 0,
         csv: bool = False) -> Dict[str, Tuple[float, float]]:
    users = make_users(n_clients, seed=seed)
    fleet = make_fleet(n_clients, seed=seed)
    settings = [
        ("unified", UnifiedTierPlanner()),
        ("rag", RAGPlanner(seed=seed)),
        ("rag_energy", RAGPlanner(seed=seed, energy_priority=8.0)),
    ]
    out = {}
    t0 = time.time()
    for name, planner in settings:
        sats, ens, hist = run_planner(planner, users, fleet, rounds)
        out[name] = (float(sats.mean()), float(ens.mean()))
        if not csv:
            print(f"{name:11s} satisfaction={sats.mean():.3f}"
                  f"±{sats.std():.3f}  rel_energy={ens.mean():.3f}"
                  f"±{ens.std():.3f}  bits={hist}")
    u, r, e = out["unified"], out["rag"], out["rag_energy"]
    if not csv:
        print(f"-- paper Fig.3 claims: personalized +10% satisfaction, "
              f"-20% energy; energy-priority trades satisfaction for "
              f"further savings")
        print(f"   ours: rag {100*(r[0]-u[0])/u[0]:+.1f}% satisfaction, "
              f"{100*(r[1]-u[1])/u[1]:+.1f}% energy; "
              f"rag_energy {100*(e[0]-u[0])/u[0]:+.1f}% satisfaction, "
              f"{100*(e[1]-u[1])/u[1]:+.1f}% energy")
    else:
        us = (time.time() - t0) / 3 * 1e6
        for name, (s, en) in out.items():
            print(f"fig3_{name},{us:.0f},sat={s:.3f};energy={en:.3f}")
    return out


if __name__ == "__main__":
    main()
