"""Paper Fig. 3 — satisfaction-score and relative-energy-cost distributions
under (a) unified-tier planning, (b) RAG-personalized planning, and
(c) RAG with server-side energy priority.

100 simulated clients (Gaussian sensitivities, Table-I contexts), several
feedback rounds so the RAG databases warm up, oracle-scored. Planning
runs the cohort-batched path (``RAGPlanner.plan_cohort`` — one retrieval
engine query per store per round, DESIGN.md §10); the table also reports
the planning-time delta vs the legacy per-client ``plan`` loop over an
identical fresh-planner 6-round trajectory (both sides warm up their own
databases from empty, so the retrieval workloads match round for round).
"""
from __future__ import annotations

import time
from collections import Counter
from typing import Dict, Tuple

import numpy as np

from repro.core.profiling import (RAGPlanner, UnifiedTierPlanner, make_fleet,
                                  make_users, plan_round, satisfaction_score,
                                  true_performance)


def run_planner(planner, users, fleet, rounds: int = 6, batched: bool = True):
    """Returns (sats, energies, bits histogram, planning seconds)."""
    plan = planner.plan_cohort if batched else planner.plan
    sats, energies, hist = [], [], Counter()
    plan_s = 0.0
    for r in range(rounds):
        t0 = time.perf_counter()
        decisions = plan_round(plan(users, fleet))
        plan_s += time.perf_counter() - t0
        for d, u, s in zip(decisions, users, fleet):
            sat = satisfaction_score(u, s, d.bits)
            perf = true_performance(u, s, d.bits)
            planner.observe_feedback(u, s, d.bits, sat, perf)
            if r == rounds - 1:
                sats.append(sat)
                energies.append(perf["energy"])
                hist[d.bits] += 1
    return np.array(sats), np.array(energies), dict(sorted(hist.items())), plan_s


def json_report() -> Dict:
    """Machine-readable smoke-scale numbers (benchmarks/run.py --json):
    Fig.3 planner means at reduced scale + the cohort-batched vs legacy
    per-client planning-time delta (DESIGN.md §10)."""
    n_clients, rounds, seed = 40, 4, 0
    users = make_users(n_clients, seed=seed)
    fleet = make_fleet(n_clients, seed=seed)
    report: Dict = {"n_clients": n_clients, "rounds": rounds, "planners": {}}
    batched_s = 0.0
    for name, planner in (
        ("unified", UnifiedTierPlanner()),
        ("rag", RAGPlanner(seed=seed)),
        ("rag_energy", RAGPlanner(seed=seed, energy_priority=8.0)),
    ):
        sats, ens, hist, plan_s = run_planner(planner, users, fleet, rounds)
        report["planners"][name] = {
            "satisfaction": float(sats.mean()),
            "rel_energy": float(ens.mean()),
            "bits_hist": {str(b): int(c) for b, c in hist.items()},
        }
        if name == "rag":
            batched_s = plan_s
    *_, legacy_s = run_planner(RAGPlanner(seed=seed), users, fleet, rounds,
                               batched=False)
    report["planning_batched_s"] = batched_s
    report["planning_legacy_s"] = legacy_s
    report["planning_speedup"] = legacy_s / max(batched_s, 1e-9)
    return report


def main(n_clients: int = 100, rounds: int = 6, seed: int = 0,
         csv: bool = False) -> Dict[str, Tuple[float, float]]:
    users = make_users(n_clients, seed=seed)
    fleet = make_fleet(n_clients, seed=seed)
    settings = [
        ("unified", UnifiedTierPlanner()),
        ("rag", RAGPlanner(seed=seed)),
        ("rag_energy", RAGPlanner(seed=seed, energy_priority=8.0)),
    ]
    # warm both planning paths at full cohort size — the first large GEMM
    # pays one-time BLAS thread-pool init and the first *non-empty* DB
    # query pays jax backend discovery (hence 2 rounds: round 0 only
    # fills the stores) — so the planning-time delta compares steady state
    run_planner(RAGPlanner(seed=seed), users, fleet, rounds=2)
    run_planner(RAGPlanner(seed=seed), users, fleet, rounds=2,
                batched=False)
    out = {}
    t0 = time.time()
    plan_batched_s = 0.0
    for name, planner in settings:
        sats, ens, hist, plan_s = run_planner(planner, users, fleet, rounds)
        out[name] = (float(sats.mean()), float(ens.mean()))
        if name == "rag":
            plan_batched_s = plan_s
        if not csv:
            print(f"{name:11s} satisfaction={sats.mean():.3f}"
                  f"±{sats.std():.3f}  rel_energy={ens.mean():.3f}"
                  f"±{ens.std():.3f}  bits={hist}")
    settings_s = time.time() - t0  # the 3 planner runs only (csv metric)
    # planning-time delta: the same RAG pipeline through the legacy
    # per-client scan loop (fresh planner, same seed/rounds)
    *_, plan_legacy_s = run_planner(RAGPlanner(seed=seed), users, fleet,
                                    rounds, batched=False)
    speedup = plan_legacy_s / max(plan_batched_s, 1e-9)
    u, r, e = out["unified"], out["rag"], out["rag_energy"]
    if not csv:
        print(f"-- paper Fig.3 claims: personalized +10% satisfaction, "
              f"-20% energy; energy-priority trades satisfaction for "
              f"further savings")
        print(f"   ours: rag {100*(r[0]-u[0])/u[0]:+.1f}% satisfaction, "
              f"{100*(r[1]-u[1])/u[1]:+.1f}% energy; "
              f"rag_energy {100*(e[0]-u[0])/u[0]:+.1f}% satisfaction, "
              f"{100*(e[1]-u[1])/u[1]:+.1f}% energy")
        print(f"   planning time ({rounds} rounds, {n_clients} clients): "
              f"{plan_batched_s*1e3:.0f}ms cohort-batched vs "
              f"{plan_legacy_s*1e3:.0f}ms per-client ({speedup:.1f}x)")
    else:
        us = settings_s / 3 * 1e6
        for name, (s, en) in out.items():
            print(f"fig3_{name},{us:.0f},sat={s:.3f};energy={en:.3f}")
        print(f"fig3_planning,{plan_batched_s/rounds*1e6:.0f},"
              f"legacy_us={plan_legacy_s/rounds*1e6:.0f};"
              f"speedup={speedup:.2f}")
    return out


if __name__ == "__main__":
    main()
