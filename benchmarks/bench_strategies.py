"""Paper Fig. 4 — per-class global-model accuracy under the three server
contribution strategies: (a) default FedAvg, (b) class-equal (boost
minority-class clients' precision), (c) majority-centric.

Runs the full MP-OTA-FL loop (quantized local training + OTA aggregation)
on the synthetic voice corpus at reduced scale; reports char accuracy per
category. The paper's effect: vs FedAvg, class-equal trades majority
accuracy for minority accuracy, majority-centric the reverse.
"""
from __future__ import annotations

import time
from typing import Dict

import numpy as np

from repro.configs.base import FLConfig
from repro.core.profiling.users import CATEGORIES
from repro.data.voice import make_eval_set
from repro.fl import FLServer

MINORITY = ("smart_home", "personal_request")
MAJORITY = ("entertainment", "general_query")


def run_strategy(strategy: str, *, rounds: int, n_clients: int,
                 per_round: int, seed: int) -> Dict[str, float]:
    cfg = FLConfig(n_clients=n_clients, clients_per_round=per_round,
                   n_rounds=rounds, local_steps=3, local_batch=6,
                   lr=2e-3, planner="rag", strategy=strategy, seed=seed)
    srv = FLServer(cfg, shard_size=16)
    srv.run(rounds)
    acc = srv.evaluate(make_eval_set(n=96, seed=seed + 555), with_loss=True)
    acc["_loss"] = srv.round_logs[-1].train_loss
    return acc


def json_report() -> Dict:
    """Machine-readable smoke-scale numbers (benchmarks/run.py --json):
    per-category accuracy under the three contribution strategies at
    reduced scale, plus minority/majority deltas vs FedAvg."""
    rounds, n_clients, per_round, seed = 3, 12, 4, 0
    report: Dict = {"rounds": rounds, "n_clients": n_clients,
                    "per_round": per_round, "strategies": {}}
    results = {}
    for strat in ("fedavg", "class_equal", "majority_centric"):
        r = run_strategy(strat, rounds=rounds, n_clients=n_clients,
                         per_round=per_round, seed=seed)
        results[strat] = r
        report["strategies"][strat] = {
            "per_category": {c: float(r[c]) for c in CATEGORIES},
            "train_loss": float(r["_loss"]),
        }
    fa = results["fedavg"]
    for strat in ("class_equal", "majority_centric"):
        r = results[strat]
        report["strategies"][strat]["minority_delta"] = float(
            np.mean([r[c] - fa[c] for c in MINORITY]))
        report["strategies"][strat]["majority_delta"] = float(
            np.mean([r[c] - fa[c] for c in MAJORITY]))
    return report


def main(rounds: int = 10, n_clients: int = 24, per_round: int = 6,
         seed: int = 0, csv: bool = False):
    results = {}
    t0 = time.time()
    for strat in ("fedavg", "class_equal", "majority_centric"):
        results[strat] = run_strategy(strat, rounds=rounds,
                                      n_clients=n_clients,
                                      per_round=per_round, seed=seed)
        if not csv:
            accs = {c: round(results[strat][c], 3) for c in CATEGORIES}
            print(f"{strat:17s} {accs} loss={results[strat]['_loss']:.3f}")
    if not csv:
        fa = results["fedavg"]
        for strat in ("class_equal", "majority_centric"):
            r = results[strat]
            d_min = np.mean([r[c] - fa[c] for c in MINORITY])
            d_maj = np.mean([r[c] - fa[c] for c in MAJORITY])
            # per-category CTC loss deltas (negative = better for that
            # class) — sensitive during CTC's blank-collapse phase where
            # the decode-accuracy metric is still flat
            dl_min = np.mean([r["loss_" + c] - fa["loss_" + c]
                              for c in MINORITY])
            dl_maj = np.mean([r["loss_" + c] - fa["loss_" + c]
                              for c in MAJORITY])
            print(f"-- {strat} vs fedavg: acc minority {d_min:+.3f} / "
                  f"majority {d_maj:+.3f}; CTC-loss minority {dl_min:+.3f} "
                  f"/ majority {dl_maj:+.3f} "
                  f"(paper: class_equal +5%/-2%, majority_centric -3%/+4%)")
    else:
        us = (time.time() - t0) / 3 * 1e6
        for strat, r in results.items():
            payload = ";".join(f"{c}={r[c]:.3f}" for c in CATEGORIES)
            print(f"fig4_{strat},{us:.0f},{payload}")
    return results


if __name__ == "__main__":
    main()
