"""Ablation (beyond-paper): which pieces of the RAG profiling pipeline
actually buy satisfaction?

Variants over 100 clients / 6 rounds (oracle-scored like Fig. 3):
- unified        : hardware tiers only (paper baseline)
- priors_only    : Eqs (1)-(4) with analytic priors, no interview, no DBs
- interview_only : + SimLLM interviews (weights/context), DBs disabled
- full_rag       : + both RAG DBs with per-round feedback (the paper)
- oracle_weights : planner given the TRUE sensitivity weights (upper bound
                   on what better profiling could add)
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.profiling import (RAGPlanner, UnifiedTierPlanner, make_fleet,
                                  make_users, plan_round, satisfaction_score,
                                  true_performance)
from repro.core.profiling.interview import InferredProfile


class PriorsOnlyPlanner(RAGPlanner):
    name = "priors_only"

    def plan(self, users, specs, **kw):
        out = []
        from repro.core.profiling.evaluator import evaluate_levels, select_level
        from repro.core.profiling.planner import PlanDecision
        for u, s in zip(users, specs):
            prof = InferredProfile(user_id=u.user_id)  # no interview signal
            levels = evaluate_levels(prof, s, self.cqf_db, self.hqp_db,
                                     strategy=self.strategy)
            best = select_level(levels)
            out.append(PlanDecision(u.user_id, best.bits, best.score, levels))
        return out

    def observe_feedback(self, *a, **kw):
        pass  # DBs stay empty


class InterviewOnlyPlanner(RAGPlanner):
    name = "interview_only"

    def observe_feedback(self, *a, **kw):
        pass  # interviews accumulate; DBs never filled


class OracleWeightsPlanner(RAGPlanner):
    name = "oracle_weights"

    def plan(self, users, specs, **kw):
        decisions = super().plan(users, specs, **kw)
        # overwrite the inferred weights with ground truth and re-evaluate
        from repro.core.profiling.evaluator import evaluate_levels, select_level
        from repro.core.profiling.planner import PlanDecision
        out = []
        for d, u, s in zip(decisions, users, specs):
            prof = self.profiles[u.user_id]
            prof = InferredProfile(
                user_id=u.user_id, location=u.location, location_conf=1.0,
                time=u.interaction_time, time_conf=1.0,
                frequency=u.frequency, frequency_conf=1.0,
                sens={f: 3.0 * w for f, w in u.weights.items()},
                category_signal=dict(u.category_mix))
            levels = evaluate_levels(prof, s, self.cqf_db, self.hqp_db,
                                     strategy=self.strategy)
            best = select_level(levels)
            out.append(PlanDecision(u.user_id, best.bits, best.score, levels))
        return out


def run(planner, users, fleet, rounds=6):
    sats, ens = [], []
    for r in range(rounds):
        for d, u, s in zip(plan_round(planner.plan(users, fleet)), users, fleet):
            sat = satisfaction_score(u, s, d.bits)
            perf = true_performance(u, s, d.bits)
            planner.observe_feedback(u, s, d.bits, sat, perf)
            if r == rounds - 1:
                sats.append(sat)
                ens.append(perf["energy"])
    return float(np.mean(sats)), float(np.mean(ens))


def main(n=100, seed=0, csv: bool = False):
    users = make_users(n, seed=seed)
    fleet = make_fleet(n, seed=seed)
    variants = [
        ("unified", UnifiedTierPlanner()),
        ("priors_only", PriorsOnlyPlanner(seed=seed)),
        ("interview_only", InterviewOnlyPlanner(seed=seed)),
        ("full_rag", RAGPlanner(seed=seed)),
        ("oracle_weights", OracleWeightsPlanner(seed=seed)),
    ]
    t0 = time.time()
    out = {}
    for name, planner in variants:
        sat, en = run(planner, users, fleet)
        out[name] = (sat, en)
        if not csv:
            print(f"{name:15s} satisfaction={sat:.3f} rel_energy={en:.3f}")
    if csv:
        us = (time.time() - t0) / len(variants) * 1e6
        for name, (sat, en) in out.items():
            print(f"ablation_{name},{us:.0f},sat={sat:.3f};energy={en:.3f}")
    return out


if __name__ == "__main__":
    main()
