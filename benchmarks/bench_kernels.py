"""Kernel microbenchmarks: Pallas (interpret mode on CPU — indicative only;
the BlockSpec tiling is the TPU artifact) vs the pure-jnp references, plus
the OTA communication-cost table (channel uses: OTA vs digital uplink).
"""
from __future__ import annotations

import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ota
from repro.core.quant import qrange
from repro.kernels import ops, ref


def _time(fn: Callable, *args, reps: int = 5) -> float:
    fn(*args)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6  # us


def main(csv: bool = False):
    rows = []
    x = jnp.asarray(np.random.RandomState(0).randn(1 << 16), jnp.float32)
    scale = jnp.max(jnp.abs(x)) / qrange(8)
    rows.append(("fake_quant_pallas_64k", _time(
        lambda v: ops.fake_quant(v, 8), x), "interpret"))
    rows.append(("fake_quant_jnp_64k", _time(
        jax.jit(lambda v: ref.fake_quant_ref(v, scale, 8)), x), "ref"))

    K, M = 10, 1 << 15
    xs = jnp.asarray(np.random.RandomState(1).randn(K, M), jnp.float32)
    w = jnp.ones((K,)) / K
    noise = jnp.zeros((M,))
    rows.append(("ota_aggregate_pallas_10x32k", _time(
        lambda a: ops.ota_aggregate(a, w, noise, jnp.float32(0.1)), xs),
        "interpret"))
    rows.append(("ota_aggregate_jnp_10x32k", _time(
        jax.jit(lambda a: ref.ota_aggregate_ref(a, w, noise, 0.1)), xs),
        "ref"))

    xx = jnp.asarray(np.random.RandomState(2).randn(256, 512), jnp.float32)
    ww = jnp.asarray(np.random.RandomState(3).randn(512, 256), jnp.float32)
    wq, sc = ops.quantize_weights(ww, 8)
    rows.append(("qmatmul_pallas_256x512x256", _time(
        lambda a: ops.qmatmul(a, wq, sc), xx), "interpret"))
    rows.append(("qmatmul_jnp_256x512x256", _time(
        jax.jit(lambda a: ref.qmatmul_ref(a, wq, sc)), xx), "ref"))

    # OTA vs digital uplink channel uses (the MP-OTA-FL efficiency table)
    n_params = 5_000_000
    bits = [4, 8, 8, 16, 16, 16, 32] * 3  # a 21-client round
    uses_ota = ota.channel_uses(bits, n_params)
    uses_dig = ota.digital_uplink_bits(bits, n_params)
    rows.append(("ota_channel_uses_21clients", uses_ota, "symbols"))
    rows.append(("digital_uplink_bits_21clients", uses_dig,
                 f"{uses_dig/ (uses_ota*32):.1f}x OTA cost at 32b/symbol"))

    for name, val, extra in rows:
        print(f"{name},{val:.0f},{extra}")
    return rows


if __name__ == "__main__":
    main()
