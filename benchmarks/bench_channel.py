"""Physical OTA channel sweep: SNR x truncation threshold (DESIGN.md §12).

The fading channel (``core/channel.py``) turns two radio knobs into
aggregation-quality levers: the receiver SNR sets the AWGN floor, and
the truncation threshold trades participation (clients in a deep fade
are excluded) against misalignment (survivors whose power budget can't
fully invert arrive scaled by g_k < 1). This bench sweeps both over a
mixed-precision packed cohort and reports, per (snr_db, fade_threshold)
cell:

- participation rate (surviving clients / cohort) and mean misalignment
  residual 1 - g_k over survivors;
- aggregate error vs the ideal channel: relative MSE between the fading
  aggregate (gains in the fused pass) and the same cohort aggregated at
  unit gain with no noise.

``--smoke`` is the CI mode (scripts/tier1.sh), asserting the PR's two
acceptance bars:

- **unit-channel bit-equality**: ``gains=ones`` == ``gains=None`` —
  bitwise, barrier (``ota_aggregate_packed``) AND streaming
  (``OtaAccumulator``) modes, jnp-oracle AND Pallas-kernel paths (the
  legacy ``fade_threshold=0.0`` config so the coin-flip draw passes
  everyone, making the two programs comparable);
- **power control flattens the channel**: the post-inversion effective
  gains' relative spread (std/mean over survivors) shrinks vs the
  no-power-control baseline where every client transmits at the budget
  cap and arrives scaled by its raw |h_k|.

Usage: python benchmarks/bench_channel.py [--csv] [--smoke]
Runnable standalone (self-locates ``src/``) or via scripts/tier1.sh.
"""
from __future__ import annotations

import argparse
import pathlib
import sys

try:
    import repro  # noqa: F401  (importability probe)
except ImportError:  # standalone invocation: put <repo>/src on sys.path
    sys.path.insert(
        0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import channel as chan
from repro.core import ota, packing

K_DEFAULT = 16
M_DEFAULT = 1 << 14
POWER_BUDGET = 4.0  # sqrt(P) = 2: weak channels hit the cap -> misalignment

SNR_SWEEP = [5.0, 10.0, 20.0]           # receiver SNR (dB)
THRESH_SWEEP = [0.0, 0.05, 0.2, 0.5]    # |h|^2 truncation thresholds


def _packed_cohort(K: int, M: int, seed: int = 0):
    """Synthetic mixed-precision packed cohort + layout + round key."""
    rng = np.random.RandomState(seed)
    tree = {"w": jnp.asarray(rng.randn(M).astype(np.float32) * 0.01)}
    layout = packing.make_layout(tree)
    bits = [(4, 8, 8, 16, 32)[i % 5] for i in range(K)]
    weights = [1.0 + (i % 3) for i in range(K)]
    key = jax.random.key(seed + 11)
    sr = ota.derive_sr_seed(key)
    rows = []
    for i, b in enumerate(bits):
        up = {"w": jnp.asarray(rng.randn(M).astype(np.float32) * 0.01)}
        rows.append(ota.quantize_uplink(packing.pack(up, layout), b, sr, i,
                                        block=packing.QUANT_BLOCK))
    return rows, weights, layout, key


# ---------------------------------------------------------------------------
# SNR x threshold sweep
# ---------------------------------------------------------------------------


def sweep_cell(snr_db: float, threshold: float, *, K: int = K_DEFAULT,
               M: int = M_DEFAULT, seed: int = 0):
    """One (snr, threshold) cell: participation, misalignment, rel-MSE."""
    rows, weights, layout, key = _packed_cohort(K, M, seed=seed)
    model = chan.ChannelModel(chan.ChannelConfig(
        fade_threshold=threshold, power_budget=POWER_BUDGET))
    state = model.sample(key, K)
    gains = state.gains
    cfg = ota.OTAConfig(snr_db=snr_db)
    agg, info = ota.ota_aggregate_packed(key, rows, None, weights, layout,
                                         cfg, gains=gains, use_kernel=False)
    # ideal reference: unit gains, effectively-noiseless receiver
    ideal, _ = ota.ota_aggregate_packed(
        key, rows, None, weights, layout, ota.OTAConfig(snr_db=200.0),
        gains=jnp.ones((K,), jnp.float32), use_kernel=False)
    err = sum(float(jnp.sum((a - b) ** 2)) for a, b in
              zip(jax.tree.leaves(agg), jax.tree.leaves(ideal)))
    ref = sum(float(jnp.sum(b ** 2)) for b in jax.tree.leaves(ideal))
    g = np.asarray(jax.device_get(gains))
    surv = g > 0
    mis = float((1.0 - g[surv]).mean()) if surv.any() else 1.0
    return {
        "snr_db": snr_db,
        "fade_threshold": threshold,
        "participation": float(surv.mean()),
        "mean_misalignment": mis,
        "rel_mse_vs_ideal": err / max(ref, 1e-30),
    }


# ---------------------------------------------------------------------------
# smoke bars
# ---------------------------------------------------------------------------


def check_unit_channel_bit_equality(K: int = 6, M: int = 1 << 14) -> None:
    """gains=ones == gains=None bitwise — barrier and streaming modes,
    oracle and kernel paths.

    Uses ``fade_threshold=0.0`` so the legacy path's coin-flip passes
    every client (|h|^2 >= 0 always) — the two programs then compute the
    same weighted superposition and must agree to the bit.
    """
    rows, weights, layout, key = _packed_cohort(K, M)
    cfg = ota.OTAConfig(snr_db=20.0, fade_threshold=0.0)
    ones = jnp.ones((K,), jnp.float32)
    for use_kernel in (False, True):
        legacy, _ = ota.ota_aggregate_packed(key, rows, None, weights,
                                             layout, cfg,
                                             use_kernel=use_kernel)
        unit, _ = ota.ota_aggregate_packed(key, rows, None, weights, layout,
                                           cfg, gains=ones,
                                           use_kernel=use_kernel)
        for a, b in zip(jax.tree.leaves(legacy), jax.tree.leaves(unit)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # streaming: the same contract through the persistent accumulator
        _, _, w = ota.round_channel(key, jnp.asarray(weights, jnp.float32),
                                    cfg=cfg)
        acc0 = ota.OtaAccumulator(layout, cfg, use_kernel=use_kernel)
        acc1 = ota.OtaAccumulator(layout, cfg, use_kernel=use_kernel)
        got0, _ = acc0.fold(rows, w).finalize(key)
        got1, _ = acc1.fold(rows, w, gains=ones).finalize(key)
        for a, b in zip(jax.tree.leaves(got0), jax.tree.leaves(got1)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(legacy), jax.tree.leaves(got0)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def check_power_control_flattens_gains(n: int = 256, seed: int = 7):
    """Truncated inversion must shrink the survivors' gain spread vs the
    no-power-control baseline (everyone at the cap, raw |h| scaling).
    Returns (controlled spread, uncontrolled spread), asserting shrink.
    """
    model = chan.ChannelModel(chan.ChannelConfig(
        fade_threshold=0.05, power_budget=POWER_BUDGET))
    state = model.sample(jax.random.key(seed), n)
    g = np.asarray(jax.device_get(state.gains))
    unc = np.asarray(jax.device_get(model.uncontrolled_gains(state)))
    surv = g > 0
    rel = lambda x: float(x.std() / max(x.mean(), 1e-12))  # noqa: E731
    inv_spread, unc_spread = rel(g[surv]), rel(unc[surv])
    assert inv_spread < unc_spread, (inv_spread, unc_spread)
    return inv_spread, unc_spread


def smoke() -> int:
    """CI mode: bit-equality + variance-shrink acceptance bars."""
    check_unit_channel_bit_equality()
    inv, unc = check_power_control_flattens_gains()
    print(f"smoke OK: unit channel (gains=ones) == legacy gains=None "
          f"bit-equal, barrier + streaming, oracle + kernel; inversion "
          f"gain spread {inv:.3f} < uncontrolled {unc:.3f}")
    return 0


def json_report() -> dict:
    """Machine-readable smoke-scale numbers (benchmarks/run.py --json)."""
    inv, unc = check_power_control_flattens_gains()
    cells = [sweep_cell(snr, th) for snr in (10.0, 20.0)
             for th in (0.05, 0.2)]
    return {
        "K": K_DEFAULT, "M": M_DEFAULT, "power_budget": POWER_BUDGET,
        "inversion_gain_spread": inv,
        "uncontrolled_gain_spread": unc,
        "cells": cells,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--csv", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: bit-equality + variance-shrink asserts")
    args = ap.parse_args()

    if args.smoke:
        raise SystemExit(smoke())

    check_unit_channel_bit_equality()
    print("unit channel == legacy path: bit-equal (barrier + streaming)")
    inv, unc = check_power_control_flattens_gains()
    print(f"survivor gain spread: inversion {inv:.3f} vs "
          f"no-power-control {unc:.3f}")
    if args.csv:
        print("snr_db,fade_threshold,participation,mean_misalignment,"
              "rel_mse_vs_ideal")
    else:
        print(f"{'snr':>5} {'thresh':>7} {'partic':>7} {'misalign':>9} "
              f"{'rel_mse':>10}")
    for snr in SNR_SWEEP:
        for th in THRESH_SWEEP:
            c = sweep_cell(snr, th)
            if args.csv:
                print(f"{snr},{th},{c['participation']:.3f},"
                      f"{c['mean_misalignment']:.4f},"
                      f"{c['rel_mse_vs_ideal']:.5f}")
            else:
                print(f"{snr:>5.1f} {th:>7.2f} {c['participation']:>7.2f} "
                      f"{c['mean_misalignment']:>9.4f} "
                      f"{c['rel_mse_vs_ideal']:>10.5f}")


if __name__ == "__main__":
    main()
