"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads benchmarks/dryrun_results.json (produced by repro.launch.dryrun) and
prints per (arch x shape): the three roofline terms, the bottleneck, and
MODEL_FLOPS / HLO_FLOPs utilisation.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List

N_CHIPS = 256  # single-pod table

HERE = os.path.dirname(__file__)
RESULTS = os.path.join(HERE, "dryrun_results.json")


def load(multi_pod: bool = False) -> List[Dict]:
    with open(RESULTS) as f:
        rs = json.load(f)
    return [r for r in rs
            if r.get("multi_pod", False) == multi_pod
            and r.get("status") == "ok"]


def rows(multi_pod: bool = False) -> List[Dict]:
    out = []
    for r in sorted(load(multi_pod), key=lambda r: (r["arch"], r["shape"])):
        flops = r.get("flops_extrap") or r.get("flops") or 0
        model_fl = (r.get("model_flops") or 0) / N_CHIPS  # per chip
        out.append({
            "arch": r["arch"], "shape": r["shape"],
            "t_compute": r["t_compute_s"], "t_memory": r["t_memory_s"],
            "t_collective": r["t_collective_s"],
            "bottleneck": r["bottleneck"],
            "useful_ratio": model_fl / flops if flops else float("nan"),
            "params": r.get("n_params"),
            "compile_s": r.get("compile_s"),
        })
    return out


def main(csv: bool = False):
    table = rows()
    if not table:
        print("no dry-run results yet; run: python -m repro.launch.dryrun --all")
        return []
    if csv:
        for r in table:
            print(f"roofline_{r['arch']}_{r['shape']},"
                  f"{max(r['t_compute'], r['t_memory'], r['t_collective'])*1e6:.0f},"
                  f"bottleneck={r['bottleneck']};useful={r['useful_ratio']:.2f}")
    else:
        hdr = (f"{'arch':18s} {'shape':12s} {'t_comp(s)':>10s} "
               f"{'t_mem(s)':>10s} {'t_coll(s)':>10s} {'bound':>10s} "
               f"{'useful':>7s}")
        print(hdr)
        for r in table:
            print(f"{r['arch']:18s} {r['shape']:12s} "
                  f"{r['t_compute']:10.3g} {r['t_memory']:10.3g} "
                  f"{r['t_collective']:10.3g} {r['bottleneck']:>10s} "
                  f"{r['useful_ratio']:7.2f}")
    return table


if __name__ == "__main__":
    main()
