"""Mesh-sharded data planes: equivalence bars + weak-scaling sweep
(DESIGN.md §15).

Both data planes shard over the ``data`` axis of a 1-D device mesh
(``launch.mesh.make_data_mesh``): the OTA fold places its SYMBOL
(column) axis across shards — per-column sums never reassociate, the
cross-shard combine is pure concatenation — and the retrieval engine
row-shards the arena slab, runs the fused top-k per shard, and re-merges
lanes under the engine tie contract. Sharded == single-host *bitwise*
is the whole point, so this bench asserts it rather than timing around
it.

``--smoke`` is the CI mode (scripts/tier1.sh + the multidevice CI
lane), asserting the PR's acceptance bars on a forced-multi-device CPU
mesh:

- **bit-equality**: ``ota_aggregate_packed(..., mesh=4 shards)`` equals
  the unsharded aggregate byte-for-byte on a mixed-precision cohort
  (one-shot AND two-wave streaming accumulator), and the mesh
  retrieval engine equals the unsharded fused top-k byte-for-byte on
  f32 and int8 arenas;
- **per-shard residency**: at 4 shards each device holds <= 1/2 of the
  single-host resident bytes — the retrieval slab slice
  (``ArenaStore.shard_nbytes``) and the OTA column chunk
  (``core.ota._shard_chunk``) both shrink >= 2x.

The default mode prints a weak-scaling table over 1/2/4/8 shards:
fold / query wall time and the per-shard resident fraction.

Usage: python benchmarks/bench_mesh.py [--smoke] [--json-stdout]
Runnable standalone (self-locates ``src/``, forces 8 host devices
before the first jax import) or via benchmarks/run.py --json, which
re-execs this file in a child interpreter when jax is already
initialised single-device.
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import subprocess
import sys
import time

try:
    import repro  # noqa: F401  (importability probe)
except ImportError:  # standalone invocation: put <repo>/src on sys.path
    sys.path.insert(
        0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

# a data mesh needs real (forced) host devices, and XLA_FLAGS only takes
# effect before the first jax import — so claim the devices at module
# import when jax isn't loaded yet (standalone runs); anything importing
# us with jax already up single-device goes through _respawn() instead
DEVICE_COUNT = 8
if "jax" not in sys.modules and "host_platform_device_count" not in os.environ.get(
    "XLA_FLAGS", ""
):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={DEVICE_COUNT}"
    ).strip()

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ota, packing
from repro.kernels import ops as kops
from repro.launch.mesh import make_data_mesh
from repro.retrieval.arena import ArenaStore
from repro.retrieval.engine import RetrievalEngine

K_DEFAULT = 8
M_DEFAULT = 1 << 14
N_RECORDS = 3072
DIM = 64
SHARD_SWEEP = (1, 2, 4, 8)


def _packed_cohort(K: int, M: int, seed: int = 0):
    """Synthetic mixed-precision packed cohort + layout + round key."""
    rng = np.random.RandomState(seed)
    tree = {"w": jnp.asarray(rng.randn(M).astype(np.float32) * 0.01)}
    layout = packing.make_layout(tree)
    bits = [(4, 8, 8, 16, 32)[i % 5] for i in range(K)]
    weights = [1.0 + (i % 3) for i in range(K)]
    key = jax.random.key(seed + 11)
    sr = ota.derive_sr_seed(key)
    rows = []
    for i, b in enumerate(bits):
        up = {"w": jnp.asarray(rng.randn(M).astype(np.float32) * 0.01)}
        rows.append(ota.quantize_uplink(packing.pack(up, layout), b, sr, i,
                                        block=packing.QUANT_BLOCK))
    return rows, weights, layout, key


def _leaves_bytes_equal(a, b) -> bool:
    return all(
        np.asarray(x).tobytes() == np.asarray(y).tobytes()
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))
    )


def _arena(storage: str, seed: int = 3) -> ArenaStore:
    rng = np.random.RandomState(seed)
    store = ArenaStore(DIM, storage=storage)
    store.add_batch(rng.randn(N_RECORDS, DIM).astype(np.float32))
    return store


def _queries(seed: int = 4) -> np.ndarray:
    return np.random.RandomState(seed).randn(8, DIM).astype(np.float32)


# ---------------------------------------------------------------------------
# smoke bars
# ---------------------------------------------------------------------------


def check_ota_bit_equality(n_shards: int = 4) -> None:
    """Sharded OTA fold == single-host aggregate, byte for byte —
    one-shot barrier mode and the two-wave streaming accumulator."""
    rows, weights, layout, key = _packed_cohort(K_DEFAULT, M_DEFAULT)
    cfg = ota.OTAConfig(snr_db=20.0, fade_threshold=0.0)
    mesh = make_data_mesh(n_shards)
    ref, _ = ota.ota_aggregate_packed(key, rows, None, weights, layout, cfg,
                                      use_kernel=False)
    sh, _ = ota.ota_aggregate_packed(key, rows, None, weights, layout, cfg,
                                     use_kernel=False, mesh=mesh)
    assert _leaves_bytes_equal(ref, sh), "one-shot sharded fold not bitwise"
    # streaming: two waves through the persistent accumulator
    _, _, w = ota.round_channel(
        key, jnp.asarray(weights, jnp.float32), cfg=cfg)

    def stream(mesh_):
        acc = ota.OtaAccumulator(layout, cfg, use_kernel=False, mesh=mesh_)
        acc.fold(rows[:3], w[:3])
        acc.fold(rows[3:], w[3:])
        return acc.finalize(key)[0]

    assert _leaves_bytes_equal(stream(None), stream(mesh)), \
        "streaming sharded fold not bitwise"


def check_retrieval_bit_equality(n_shards: int = 4, k: int = 32) -> None:
    """Mesh retrieval engine == unsharded fused top-k, byte for byte,
    f32 and int8 arenas."""
    qm = _queries()
    for storage in ("f32", "int8"):
        store = _arena(storage)
        data, scales = store.raw()
        s0, i0 = kops.topk_cosine(
            jnp.asarray(qm), jnp.asarray(data),
            None if scales is None else jnp.asarray(scales),
            jnp.int32(len(store)), k=k, use_kernel=False)
        eng = RetrievalEngine(store, use_kernel=False,
                              mesh=make_data_mesh(n_shards))
        s1, i1 = eng.topk(qm, k)
        assert np.asarray(s0).tobytes() == s1.tobytes(), storage
        assert np.asarray(i0).tobytes() == i1.tobytes(), storage


def check_shard_residency(n_shards: int = 4) -> tuple:
    """Per-shard resident bytes shrink >= 2x at 4 shards, both planes.
    Returns (retrieval bytes ratio, ota column-chunk ratio)."""
    store = _arena("int8")
    bytes_ratio = store.shard_nbytes(1) / store.shard_nbytes(n_shards)
    kinds = (("int4", packing.QUANT_BLOCK), ("int8", packing.QUANT_BLOCK),
             ("int16", packing.QUANT_BLOCK), ("float32", 0))
    chunk_ratio = M_DEFAULT / ota._shard_chunk(M_DEFAULT, n_shards, kinds)
    assert bytes_ratio >= 2.0, bytes_ratio
    assert chunk_ratio >= 2.0, chunk_ratio
    return bytes_ratio, chunk_ratio


# ---------------------------------------------------------------------------
# weak-scaling sweep
# ---------------------------------------------------------------------------


def _time_ms(fn, reps: int = 3) -> float:
    fn()  # warm the caches (trace + compile)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e3


def sweep() -> list:
    """Per shard count: fold/query wall ms + resident fraction."""
    rows, weights, layout, key = _packed_cohort(K_DEFAULT, M_DEFAULT)
    cfg = ota.OTAConfig(snr_db=20.0, fade_threshold=0.0)
    store = _arena("int8")
    qm = _queries()
    out = []
    for d in SHARD_SWEEP:
        if d > len(jax.devices()):
            continue
        mesh = None if d == 1 else make_data_mesh(d)
        fold_ms = _time_ms(lambda: ota.ota_aggregate_packed(
            key, rows, None, weights, layout, cfg, use_kernel=False,
            mesh=mesh))
        eng = RetrievalEngine(store, use_kernel=False, mesh=mesh) \
            if mesh is not None else RetrievalEngine(store, use_kernel=True)
        query_ms = _time_ms(lambda: eng.topk(qm, 32))
        kinds = (("int8", packing.QUANT_BLOCK),)
        out.append({
            "shards": d,
            "fold_ms": fold_ms,
            "query_ms": query_ms,
            "ota_resident_frac":
                ota._shard_chunk(M_DEFAULT, d, kinds) / M_DEFAULT,
            "slab_resident_frac":
                store.shard_nbytes(d) / store.shard_nbytes(1),
        })
    return out


# ---------------------------------------------------------------------------
# entrypoints
# ---------------------------------------------------------------------------


def _respawn(args: list) -> subprocess.CompletedProcess:
    """Re-exec this file in a child interpreter with forced devices (jax
    in this process is already initialised with too few)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={DEVICE_COUNT}"
    return subprocess.run([sys.executable, __file__, *args],
                          capture_output=True, text=True, env=env)


def smoke() -> int:
    """CI mode: bit-equality + residency acceptance bars."""
    if len(jax.devices()) < 4:
        out = _respawn(["--smoke"])
        sys.stdout.write(out.stdout)
        sys.stderr.write(out.stderr)
        return out.returncode
    check_ota_bit_equality()
    check_retrieval_bit_equality()
    bytes_ratio, chunk_ratio = check_shard_residency()
    print(f"smoke OK: 4-shard mesh OTA fold + retrieval top-k bit-equal "
          f"to single-host (one-shot + streaming, f32 + int8); per-shard "
          f"residency: slab 1/{bytes_ratio:.0f}, ota chunk "
          f"1/{chunk_ratio:.0f}")
    return 0


def json_report() -> dict:
    """Machine-readable smoke-scale numbers (benchmarks/run.py --json).

    When the hosting process already initialised jax single-device (the
    run.py case), computes in a re-exec'd child and parses its stdout.
    """
    if len(jax.devices()) < 4:
        out = _respawn(["--json-stdout"])
        if out.returncode != 0:
            raise RuntimeError(f"bench_mesh child failed:\n{out.stderr}")
        return json.loads(out.stdout)
    check_ota_bit_equality()
    check_retrieval_bit_equality()
    bytes_ratio, chunk_ratio = check_shard_residency()
    return {
        "devices": len(jax.devices()),
        "K": K_DEFAULT, "M": M_DEFAULT,
        "n_records": N_RECORDS, "dim": DIM,
        "bit_equal": True,
        "slab_bytes_ratio_4": bytes_ratio,
        "ota_chunk_ratio_4": chunk_ratio,
        "sweep": sweep(),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: bit-equality + residency asserts")
    ap.add_argument("--json-stdout", action="store_true",
                    help="print the json_report payload to stdout")
    args = ap.parse_args()

    if args.smoke:
        raise SystemExit(smoke())
    if args.json_stdout:
        print(json.dumps(json_report(), indent=2, sort_keys=True))
        return

    check_ota_bit_equality()
    print("4-shard mesh == single-host: bit-equal (OTA + retrieval)")
    print(f"{'shards':>6} {'fold_ms':>9} {'query_ms':>9} "
          f"{'ota_frac':>9} {'slab_frac':>10}")
    for row in sweep():
        print(f"{row['shards']:>6} {row['fold_ms']:>9.2f} "
              f"{row['query_ms']:>9.2f} {row['ota_resident_frac']:>9.3f} "
              f"{row['slab_resident_frac']:>10.3f}")


if __name__ == "__main__":
    main()
