"""Old-vs-new OTA aggregation data plane: per-tree Python loop vs the
fused flat (K, M) pipeline.

Sweeps cohort size K and model size M and reports wall time per round for

- ``legacy``: ``ota.ota_aggregate_pertree`` — the seed implementation's
  structure: an unjitted Python loop over clients x pytree leaves, three
  materialized passes per client (quantize / dequantize / weighted add).
- ``flat``:   ``ota.ota_aggregate_packed`` — pack once (excluded; clients
  pack at the edge), then ONE jitted program: fused stochastic quantize +
  superposition + AWGN epilogue.

On CPU the flat path runs the XLA-fused jnp formulation of the kernel
(interpret-mode Pallas is a correctness tool, not a perf path) — the
"CPU-interpret-off jit path". On TPU it runs the Pallas kernel.

Usage:  python benchmarks/bench_aggregation.py [--full] [--csv]
``--full`` extends the sweep to M = 10M+ parameter models.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ota, packing

# K sweep at fixed M, then M sweep at fixed K. (K, M) pairs.
QUICK_SWEEP = [
    (8, 1 << 20), (32, 1 << 20), (128, 1 << 20), (256, 1 << 20),
    (32, 1 << 17),
]
FULL_EXTRA = [
    (32, 10_000_000), (8, 16_000_000),
]


def _tree_of(M: int, seed: int, n_leaves: int = 6):
    """Synthetic update pytree with n_leaves uneven leaves summing to ~M."""
    rng = np.random.RandomState(seed)
    sizes = [M // n_leaves] * (n_leaves - 1)
    sizes.append(M - sum(sizes))
    return {f"layer{j}": jnp.asarray(rng.randn(s).astype(np.float32) * 0.01)
            for j, s in enumerate(sizes)}


def _bits(K: int):
    return [(4, 8, 8, 16, 32)[i % 5] for i in range(K)]


def bench_pair(K: int, M: int, reps: int = 3, legacy_reps: int = 1,
               legacy_cap_elems: float = 2e8):
    """Returns (legacy_s, flat_s, speedup). legacy is skipped (nan) above
    legacy_cap_elems K*M to keep the sweep finishable."""
    ups = [_tree_of(M, seed=i) for i in range(K)]
    bits = _bits(K)
    weights = [1.0 + (i % 3) for i in range(K)]
    cfg = ota.OTAConfig(snr_db=20.0)
    layout = packing.make_layout(ups[0])
    X = packing.pack_batch(ups, layout)
    jax.block_until_ready(X)

    # ---- new flat path (steady state: layout cached, program compiled)
    key = jax.random.key(0)
    out, _ = ota.ota_aggregate_packed(key, X, bits, weights, layout, cfg)
    jax.block_until_ready(jax.tree.leaves(out))
    t0 = time.perf_counter()
    for r in range(reps):
        out, _ = ota.ota_aggregate_packed(jax.random.key(r), X, bits,
                                          weights, layout, cfg)
    jax.block_until_ready(jax.tree.leaves(out))
    flat_s = (time.perf_counter() - t0) / reps

    # ---- legacy per-tree loop
    if K * M > legacy_cap_elems:
        return float("nan"), flat_s, float("nan")
    out, _ = ota.ota_aggregate_pertree(key, ups, bits, weights, cfg)
    jax.block_until_ready(jax.tree.leaves(out))
    t0 = time.perf_counter()
    for r in range(legacy_reps):
        out, _ = ota.ota_aggregate_pertree(jax.random.key(r), ups, bits,
                                           weights, cfg)
    jax.block_until_ready(jax.tree.leaves(out))
    legacy_s = (time.perf_counter() - t0) / legacy_reps
    return legacy_s, flat_s, legacy_s / flat_s


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="include 10M+ param configs")
    ap.add_argument("--csv", action="store_true")
    args = ap.parse_args()

    sweep = QUICK_SWEEP + (FULL_EXTRA if args.full else [])
    header = f"{'K':>4} {'M':>10} {'legacy_ms':>10} {'flat_ms':>9} {'speedup':>8}"
    if args.csv:
        print("K,M,legacy_ms,flat_ms,speedup")
    else:
        print(header)
    rows = []
    for K, M in sweep:
        legacy_s, flat_s, speed = bench_pair(K, M)
        rows.append((K, M, legacy_s, flat_s, speed))
        if args.csv:
            print(f"{K},{M},{legacy_s*1e3:.1f},{flat_s*1e3:.1f},{speed:.1f}")
        else:
            print(f"{K:>4} {M:>10} {legacy_s*1e3:>10.1f} {flat_s*1e3:>9.1f} "
                  f"{speed:>7.1f}x")
    return rows


if __name__ == "__main__":
    main()
