"""Old-vs-new OTA aggregation data plane: per-tree Python loop vs the
fused flat (K, M) pipeline.

Sweeps cohort size K and model size M and reports wall time per round for

- ``legacy``: ``ota.ota_aggregate_pertree`` — the seed implementation's
  structure: an unjitted Python loop over clients x pytree leaves, three
  materialized passes per client (quantize / dequantize / weighted add).
- ``flat``:   ``ota.ota_aggregate_packed`` — pack once (excluded; clients
  pack at the edge), then ONE jitted program: fused stochastic quantize +
  superposition + AWGN epilogue.
- ``packed``: the same entry point fed quantized+bit-packed wire rows
  (``ota.quantize_uplink`` -> ``packing.PackedRow``): clients quantize at
  the edge, the fused pass dequantizes in-tile. The table also reports
  **bytes-on-wire** — what the cohort's uplink actually occupies (int4 =
  two symbols/byte + one f32 scale) vs the f32 rows it replaces; a pure
  4-bit cohort must come in at <= 1/7 of f32 (acceptance bar; the exact
  figure is ~1/8).

On CPU the flat path runs the XLA-fused jnp formulation of the kernel
(interpret-mode Pallas is a correctness tool, not a perf path) — the
"CPU-interpret-off jit path". On TPU it runs the Pallas kernel.

The table also reports **quantization error**: per-row vs blockwise
(``packing.QUANT_BLOCK`` symbols per scale) reconstruction MSE on a
heavy-tailed synthetic update — the case the paper's precision planner
creates, where one large leaf shares a row with many small ones and a
single per-update scale inflates every low-bit client's integer grid.

Usage:  python benchmarks/bench_aggregation.py [--full] [--csv] [--smoke]
``--full`` extends the sweep to M = 10M+ parameter models. ``--smoke``
is the CI mode (scripts/tier1.sh): one tiny config, asserts the 4-bit
wire-byte bar (at the default quantization block), the round-trip
(uplink + downlink) wire bar — 4-bit up / 8-bit down must come in at
<= 1/4 of f32 on both legs — packed-vs-f32 aggregate equivalence, and
blockwise MSE <= per-row MSE on the heavy-tailed fixture; exits
non-zero on violation. Runnable standalone
(no PYTHONPATH needed — it self-locates ``src/``) or via
scripts/tier1.sh.
"""
from __future__ import annotations

import argparse
import pathlib
import sys
import time

try:
    import repro  # noqa: F401  (importability probe)
except ImportError:  # standalone invocation: put <repo>/src on sys.path
    sys.path.insert(
        0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ota, packing, wire

# K sweep at fixed M, then M sweep at fixed K. (K, M) pairs.
QUICK_SWEEP = [
    (8, 1 << 20), (32, 1 << 20), (128, 1 << 20), (256, 1 << 20),
    (32, 1 << 17),
]
FULL_EXTRA = [
    (32, 10_000_000), (8, 16_000_000),
]


def _tree_of(M: int, seed: int, n_leaves: int = 6):
    """Synthetic update pytree with n_leaves uneven leaves summing to ~M."""
    rng = np.random.RandomState(seed)
    sizes = [M // n_leaves] * (n_leaves - 1)
    sizes.append(M - sum(sizes))
    return {f"layer{j}": jnp.asarray(rng.randn(s).astype(np.float32) * 0.01)
            for j, s in enumerate(sizes)}


def _bits(K: int):
    return [(4, 8, 8, 16, 32)[i % 5] for i in range(K)]


def _make_rows(X, bits, key, block: int = 0):
    """Quantize+bit-pack every client row at the edge (the wire format).

    ``block`` > 0 ships blockwise scales (one per ``block`` symbols);
    0 = the per-update scale.
    """
    sr = ota.derive_sr_seed(key)
    rows = [ota.quantize_uplink(X[i], b, sr, i, block=block)
            for i, b in enumerate(bits)]
    jax.block_until_ready([r.data for r in rows])
    return rows


def bench_pair(K: int, M: int, reps: int = 3, legacy_reps: int = 1,
               legacy_cap_elems: float = 2e8):
    """Returns (legacy_s, flat_s, packed_s, wire_ratio, speedup).

    legacy is skipped (nan) above legacy_cap_elems K*M to keep the sweep
    finishable. wire_ratio = cohort bytes-on-wire / f32-row bytes for the
    mixed 4/8/8/16/32 cohort (``_bits``).
    """
    ups = [_tree_of(M, seed=i) for i in range(K)]
    bits = _bits(K)
    weights = [1.0 + (i % 3) for i in range(K)]
    cfg = ota.OTAConfig(snr_db=20.0)
    layout = packing.make_layout(ups[0])
    X = packing.pack_batch(ups, layout)
    jax.block_until_ready(X)

    # ---- new flat path (steady state: layout cached, program compiled)
    key = jax.random.key(0)
    out, _ = ota.ota_aggregate_packed(key, X, bits, weights, layout, cfg)
    jax.block_until_ready(jax.tree.leaves(out))
    t0 = time.perf_counter()
    for r in range(reps):
        out, _ = ota.ota_aggregate_packed(jax.random.key(r), X, bits,
                                          weights, layout, cfg)
    jax.block_until_ready(jax.tree.leaves(out))
    flat_s = (time.perf_counter() - t0) / reps

    # ---- packed wire rows (client-side quantization excluded: that cost
    # lives at the edge, like packing; we time the server data plane)
    rows = _make_rows(X, bits, key)
    out, info = ota.ota_aggregate_packed(key, rows, bits, weights, layout,
                                         cfg)
    jax.block_until_ready(jax.tree.leaves(out))
    wire_ratio = info["uplink_bytes"] / info["uplink_bytes_f32"]
    t0 = time.perf_counter()
    for r in range(reps):
        out, _ = ota.ota_aggregate_packed(jax.random.key(r), rows, bits,
                                          weights, layout, cfg)
    jax.block_until_ready(jax.tree.leaves(out))
    packed_s = (time.perf_counter() - t0) / reps

    # ---- legacy per-tree loop
    if K * M > legacy_cap_elems:
        return float("nan"), flat_s, packed_s, wire_ratio, float("nan")
    out, _ = ota.ota_aggregate_pertree(key, ups, bits, weights, cfg)
    jax.block_until_ready(jax.tree.leaves(out))
    t0 = time.perf_counter()
    for r in range(legacy_reps):
        out, _ = ota.ota_aggregate_pertree(jax.random.key(r), ups, bits,
                                           weights, cfg)
    jax.block_until_ready(jax.tree.leaves(out))
    legacy_s = (time.perf_counter() - t0) / legacy_reps
    return legacy_s, flat_s, packed_s, wire_ratio, legacy_s / flat_s


def bench_4bit_wire(K: int = 8, M: int = 1 << 17, block: int = 0) -> float:
    """Pure-4-bit cohort bytes-on-wire ratio vs the f32 rows it replaces.

    This is the acceptance measurement: int4 packs two symbols per byte
    plus f32 scales (one per update, or one per ``block`` symbols for
    blockwise rows — +4 bytes/block), so the ratio lands at ~1/8 per-row
    and ~1/8 + 1/block blockwise, and must stay <= 1/7 at the default
    ``packing.QUANT_BLOCK``.
    """
    ups = [_tree_of(M, seed=i) for i in range(K)]
    layout = packing.make_layout(ups[0])
    X = packing.pack_batch(ups, layout)
    rows = _make_rows(X, [4] * K, jax.random.key(0), block=block)
    wire = sum(r.wire_nbytes for r in rows)
    f32 = 4 * layout.padded_size * K
    print(f"4-bit cohort (K={K}, M={M}, block={block or 'per-row'}): "
          f"{wire} bytes on wire vs {f32} f32 bytes -> "
          f"ratio {wire / f32:.4f} (bar: <= {1 / 7:.4f})")
    return wire / f32


def _heavy_tailed_row(M: int, seed: int = 0) -> jnp.ndarray:
    """Synthetic flat update with heterogeneous leaf magnitudes.

    Six equal runs ("leaves") at stds spanning 1e-3..10 — the mixed-
    precision failure mode where the largest leaf sets the per-update
    scale and the small leaves lose all their int4 resolution.
    """
    rng = np.random.RandomState(seed)
    stds = [1e-3, 3e-3, 1e-2, 3e-2, 1e-1, 10.0]
    n = M // len(stds)
    sizes = [n] * (len(stds) - 1) + [M - n * (len(stds) - 1)]
    runs = [rng.randn(sz) * s for sz, s in zip(sizes, stds)]
    return jnp.asarray(np.concatenate(runs).astype(np.float32))


def quant_error_report(M: int = 1 << 16,
                       block: int = packing.QUANT_BLOCK):
    """Per-row vs blockwise reconstruction MSE on the heavy-tailed row.

    Returns {bits: (per_row_mse, blockwise_mse)} and prints the table;
    the blockwise column must dominate (<=) per-row — smoke() asserts
    it. This is the accuracy half of the +4 bytes/block trade.
    """
    tree = {"w": _heavy_tailed_row(M)}
    layout = packing.make_layout(tree)
    flat = packing.pack(tree, layout)
    sr = ota.derive_sr_seed(jax.random.key(1))
    out = {}
    print(f"quantization error, heavy-tailed update (M={M}, "
          f"block={block}):")
    print(f"{'bits':>5} {'per_row_mse':>12} {'block_mse':>12} {'gain':>6}")
    for bits in (4, 8):
        per = ota.quantize_uplink(flat, bits, sr, 0)
        blk = ota.quantize_uplink(flat, bits, sr, 0, block=block)
        e_per = float(jnp.mean(
            (ota.dequantize_uplink(per, layout.size) - flat[:layout.size])
            ** 2))
        e_blk = float(jnp.mean(
            (ota.dequantize_uplink(blk, layout.size) - flat[:layout.size])
            ** 2))
        out[bits] = (e_per, e_blk)
        print(f"{bits:>5} {e_per:>12.3e} {e_blk:>12.3e} "
              f"{e_per / max(e_blk, 1e-30):>5.1f}x")
    return out


def bench_roundtrip(K: int = 4, M: int = 1 << 14,
                    up_bits: int = 4, down_bits: int = 8,
                    block: int = packing.QUANT_BLOCK) -> dict:
    """Round-trip (uplink + downlink) wire bytes vs f32 on both legs.

    The symmetric-codec measurement (DESIGN.md §13): the cohort's K
    quantized uplink rows PLUS the server's one quantized broadcast row,
    against K + 1 f32 rows. At 4-bit up / 8-bit down with blockwise
    scales the ratio lands at ~(K/8 + 1/4)/(K + 1) ~ 0.15 and must stay
    <= 1/4 (smoke acceptance bar). An f32-passthrough downlink
    (``down_bits`` >= 32) is also measured: its broadcast must occupy
    exactly the 4 * padded_size bytes of the legacy uncoded broadcast.
    """
    ups = [_tree_of(M, seed=i) for i in range(K)]
    layout = packing.make_layout(ups[0])
    X = packing.pack_batch(ups, layout)
    key = jax.random.key(5)
    rows = _make_rows(X, [up_bits] * K, key, block=block)
    up = wire.wire_bytes(rows)
    # downlink: the aggregated delta, encoded once with the downlink seed
    delta = jnp.mean(X, axis=0)
    dl_seed = ota.derive_dl_seed(key)
    down = wire.encode_row(delta, down_bits, dl_seed, 0, block=block)
    down_f32 = wire.encode_row(delta, 32, dl_seed, 0, block=block)
    f32_leg = 4 * layout.padded_size
    ratio = (up + down.wire_nbytes) / (f32_leg * (K + 1))
    print(f"round-trip (K={K}, M={M}, {up_bits}-bit up / {down_bits}-bit "
          f"down, block={block}): {up} up + {down.wire_nbytes} down bytes "
          f"vs {f32_leg * (K + 1)} f32 -> ratio {ratio:.4f} (bar: <= 0.25)")
    return {
        "uplink_bytes": up,
        "downlink_bytes": down.wire_nbytes,
        "downlink_bytes_f32": down_f32.wire_nbytes,
        "f32_leg_bytes": f32_leg,
        "roundtrip_ratio": ratio,
    }


def smoke() -> int:
    """CI mode: tiny config, hard-asserted acceptance checks (~seconds)."""
    K, M = 6, 1 << 14
    ups = [_tree_of(M, seed=i) for i in range(K)]
    bits = [4, 4, 8, 16, 32, 4]
    weights = [1.0 + (i % 3) for i in range(K)]
    cfg = ota.OTAConfig(snr_db=20.0)
    layout = packing.make_layout(ups[0])
    X = packing.pack_batch(ups, layout)
    key = jax.random.key(3)
    rows = _make_rows(X, bits, key)
    flat, _ = ota.ota_aggregate_packed(key, X, bits, weights, layout, cfg)
    packed, info = ota.ota_aggregate_packed(key, rows, bits, weights,
                                            layout, cfg)
    for a, b in zip(jax.tree.leaves(flat), jax.tree.leaves(packed)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)
    # blockwise cohort (the FL default) still aggregates, kernel == oracle
    brows = _make_rows(X, bits, key, block=packing.QUANT_BLOCK)
    b_jnp, binfo = ota.ota_aggregate_packed(key, brows, bits, weights,
                                            layout, cfg)
    b_ker, _ = ota.ota_aggregate_packed(key, brows, bits, weights, layout,
                                        cfg, use_kernel=True)
    for a, b in zip(jax.tree.leaves(b_jnp), jax.tree.leaves(b_ker)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    ratio = bench_4bit_wire(K=4, M=M, block=packing.QUANT_BLOCK)
    assert ratio <= 1 / 7, f"4-bit wire ratio {ratio} above 1/7"
    rt = bench_roundtrip(K=4, M=M)
    assert rt["roundtrip_ratio"] <= 0.25, \
        f"round-trip wire ratio {rt['roundtrip_ratio']} above 1/4"
    assert rt["downlink_bytes_f32"] == rt["f32_leg_bytes"], \
        "f32 passthrough downlink must occupy exactly the uncoded bytes"
    errs = quant_error_report(M=M)
    for b, (e_per, e_blk) in errs.items():
        assert e_blk <= e_per, \
            f"blockwise MSE {e_blk} above per-row {e_per} at {b} bits"
    print(f"smoke OK: packed == f32 aggregate, blockwise kernel == oracle "
          f"(K={K}, M={M}); mixed-cohort wire bytes "
          f"{info['uplink_bytes']}/{info['uplink_bytes_f32']} per-row, "
          f"{binfo['uplink_bytes']} blockwise; round-trip ratio "
          f"{rt['roundtrip_ratio']:.3f}")
    return 0


def json_report() -> dict:
    """Machine-readable smoke-scale numbers (benchmarks/run.py --json)."""
    K, M = 6, 1 << 14
    legacy_s, flat_s, packed_s, wire_r, speed = bench_pair(K, M, reps=2)
    ratio = bench_4bit_wire(K=4, M=M, block=packing.QUANT_BLOCK)
    rt = bench_roundtrip(K=4, M=M)
    errs = quant_error_report(M=M)
    return {
        "K": K, "M": M,
        "legacy_ms": legacy_s * 1e3, "flat_ms": flat_s * 1e3,
        "packed_ms": packed_s * 1e3, "speedup": speed,
        "mixed_cohort_wire_ratio": wire_r,
        "int4_wire_ratio": ratio, "int4_wire_bar": 1 / 7,
        "roundtrip_ratio": rt["roundtrip_ratio"],
        "roundtrip_bar": 0.25,
        "roundtrip_downlink_bytes": rt["downlink_bytes"],
        "quant_mse": {str(b): {"per_row": e[0], "blockwise": e[1]}
                      for b, e in errs.items()},
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="include 10M+ param configs")
    ap.add_argument("--csv", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: tiny config + hard acceptance asserts")
    args = ap.parse_args()

    if args.smoke:
        raise SystemExit(smoke())

    sweep = QUICK_SWEEP + (FULL_EXTRA if args.full else [])
    header = (f"{'K':>4} {'M':>10} {'legacy_ms':>10} {'flat_ms':>9} "
              f"{'packed_ms':>10} {'wire':>6} {'speedup':>8}")
    if args.csv:
        print("K,M,legacy_ms,flat_ms,packed_ms,wire_ratio,speedup")
    else:
        print(header)
    rows = []
    for K, M in sweep:
        legacy_s, flat_s, packed_s, wire, speed = bench_pair(K, M)
        rows.append((K, M, legacy_s, flat_s, packed_s, wire, speed))
        if args.csv:
            print(f"{K},{M},{legacy_s*1e3:.1f},{flat_s*1e3:.1f},"
                  f"{packed_s*1e3:.1f},{wire:.4f},{speed:.1f}")
        else:
            print(f"{K:>4} {M:>10} {legacy_s*1e3:>10.1f} {flat_s*1e3:>9.1f} "
                  f"{packed_s*1e3:>10.1f} {wire:>6.3f} {speed:>7.1f}x")
    if not args.csv:  # keep --csv output machine-parseable
        bench_4bit_wire()
        bench_4bit_wire(block=packing.QUANT_BLOCK)
        quant_error_report()
    return rows


if __name__ == "__main__":
    main()
