"""Streaming vs synchronous OTA rounds: goodput under straggler tails.

The synchronous barrier (``FLServer.run_round``) pays max-of-K latency
every round — with a lognormal compute tail the slowest of 64 clients
lands at ~15x the median — and a single silent dropout stalls the round
to the straggler timeout. The buffered engine (``StreamingFLServer``,
DESIGN.md §11) fires at cohort-fill or deadline and folds arrivals into
a persistent ``ota.OtaAccumulator``, so round time tracks the fill
quantile instead of the max.

This bench runs the *arrival simulation* (``fl.client.LatencyModel`` on
a ``make_fleet`` device population + ``fl.server.plan_stream``) over
many rounds and reports **goodput** — counted uplink rows per simulated
second — for both round disciplines, sweeping the straggler tail
(p95/p50 compute ratio) and the silent-dropout rate. The synchronous
baseline aggregates everyone when the last report lands, or at the
straggler timeout when someone never reports; the streaming engine gets
the same timeout as its deadline, a fill target, and a grace window
(late rows count, staleness-discounted — the discount does not change
goodput accounting, a counted row is a counted row).

``--smoke`` is the CI mode (scripts/tier1.sh), asserting the PR's two
acceptance bars:

- **equivalence**: folding one round's packed cohort through
  ``OtaAccumulator`` (no deadline, identical arrival set, cohort order)
  is bit-equal to the one-shot ``ota.ota_aggregate_packed`` — jnp oracle
  AND Pallas fold kernel paths;
- **goodput**: under a heavy tail (p95 = 5x median) with 10% silent
  dropout at K = 64, streaming goodput >= 2x the synchronous baseline.

Usage: python benchmarks/bench_streaming.py [--csv] [--smoke] [--rounds N]
Runnable standalone (self-locates ``src/``) or via scripts/tier1.sh.
"""
from __future__ import annotations

import argparse
import math
import pathlib
import sys

try:
    import repro  # noqa: F401  (importability probe)
except ImportError:  # standalone invocation: put <repo>/src on sys.path
    sys.path.insert(
        0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ota, packing
from repro.core.profiling.hardware import make_fleet
from repro.fl.client import LatencyModel
from repro.fl.server import plan_stream, round_rng

K_DEFAULT = 64
FILL_FRACTION = 0.75   # streaming trigger: 3/4 of the cohort has landed
TIMEOUT_MULT = 20.0    # straggler timeout, x cohort median arrival
GRACE_MULT = 2.0       # grace window, x cohort median arrival

TAIL_SWEEP = [2.0, 5.0, 10.0]     # p95/p50 compute-latency ratios
DROP_SWEEP = [0.0, 0.1, 0.3]      # silent never-reports probability


# ---------------------------------------------------------------------------
# arrival simulation -> goodput
# ---------------------------------------------------------------------------


def simulate_round(fleet, lat: LatencyModel, rng,
                   uplink_bytes: int = 1 << 16):
    """One round's simulated arrival times (inf = silent dropout)."""
    times = []
    for spec in fleet:
        t = lat.sample(spec, rng, uplink_bytes=uplink_bytes)
        times.append(math.inf if lat.dropped(spec, rng) else t)
    return times


def goodput_pair(K: int, tail: float, drop: float, *, rounds: int = 20,
                 fill_fraction: float = FILL_FRACTION, seed: int = 0):
    """Simulate ``rounds`` rounds; return (sync_goodput, stream_goodput,
    ratio) in rows/second.

    Both disciplines see the *identical* arrival sets. Synchronous: the
    round ends at the last report, or at the straggler timeout
    (TIMEOUT_MULT x the cohort's median arrival) when anyone never
    reports; every arrived row counts. Streaming: trigger at the
    ``fill_fraction`` quantile or the same timeout (as deadline), grace
    window GRACE_MULT x median; counted = on-time + late.
    """
    fleet = make_fleet(K, seed=seed)
    lat = LatencyModel.with_tail(tail, drop_prob=drop)
    sync_rows = sync_t = stream_rows = stream_t = 0.0
    for r in range(rounds):
        times = simulate_round(fleet, lat, round_rng(seed, r, salt=6151))
        finite = sorted(t for t in times if math.isfinite(t))
        if not finite:  # everyone silently dropped: both burn the timeout
            continue
        med = finite[len(finite) // 2]
        timeout = TIMEOUT_MULT * med
        # synchronous barrier: all reports in, or straggler timeout
        t_sync = max(finite) if len(finite) == K else timeout
        sync_rows += sum(1 for t in finite if t <= t_sync)
        sync_t += min(t_sync, timeout)
        # streaming: fill-or-deadline trigger + grace window
        plan = plan_stream(times, fill=max(1, math.ceil(fill_fraction * K)),
                           deadline=timeout, grace=GRACE_MULT * med)
        stream_rows += len(plan.counted)
        stream_t += plan.t_close
    sync_g = sync_rows / max(sync_t, 1e-12)
    stream_g = stream_rows / max(stream_t, 1e-12)
    return sync_g, stream_g, stream_g / max(sync_g, 1e-12)


# ---------------------------------------------------------------------------
# accumulator equivalence (the correctness half of the smoke bar)
# ---------------------------------------------------------------------------


def _packed_cohort(K: int, M: int, seed: int = 0):
    """Synthetic mixed-precision packed cohort + layout + weights."""
    rng = np.random.RandomState(seed)
    tree = {"w": jnp.asarray(rng.randn(M).astype(np.float32) * 0.01)}
    layout = packing.make_layout(tree)
    bits = [(4, 8, 8, 16, 32)[i % 5] for i in range(K)]
    weights = [1.0 + (i % 3) for i in range(K)]
    key = jax.random.key(seed + 11)
    sr = ota.derive_sr_seed(key)
    rows = []
    for i, b in enumerate(bits):
        up = {"w": jnp.asarray(rng.randn(M).astype(np.float32) * 0.01)}
        rows.append(ota.quantize_uplink(packing.pack(up, layout), b, sr, i,
                                        block=packing.QUANT_BLOCK))
    return rows, weights, layout, key


def check_accumulator_equivalence(K: int = 6, M: int = 1 << 14) -> None:
    """Assert OtaAccumulator (one batch, cohort order) == one-shot path,
    bit-for-bit, on both the jnp-oracle and Pallas-kernel folds."""
    rows, weights, layout, key = _packed_cohort(K, M)
    cfg = ota.OTAConfig(snr_db=20.0)
    for use_kernel in (False, True):
        ref, _ = ota.ota_aggregate_packed(key, rows, None, weights, layout,
                                          cfg, use_kernel=use_kernel)
        _, _, w = ota.round_channel(key, jnp.asarray(weights, jnp.float32),
                                    cfg=cfg)
        acc = ota.OtaAccumulator(layout, cfg, use_kernel=use_kernel)
        got, _ = acc.fold(rows, w).finalize(key)
        for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(got)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def smoke() -> int:
    """CI mode: equivalence + goodput acceptance bars (~seconds)."""
    check_accumulator_equivalence()
    ratios = []
    for seed in range(3):
        _, _, ratio = goodput_pair(K_DEFAULT, tail=5.0, drop=0.1,
                                   rounds=10, seed=seed)
        ratios.append(ratio)
    mean_ratio = float(np.mean(ratios))
    assert mean_ratio >= 2.0, \
        f"streaming goodput {mean_ratio:.2f}x sync, below the 2x bar"
    print(f"smoke OK: OtaAccumulator == ota_aggregate_packed bit-equal "
          f"(oracle + kernel folds); streaming goodput {mean_ratio:.2f}x "
          f"sync at K={K_DEFAULT}, tail p95/p50=5, drop=10% (bar: >= 2x)")
    return 0


def json_report() -> dict:
    """Machine-readable smoke-scale numbers (benchmarks/run.py --json)."""
    sync_g, stream_g, ratio = goodput_pair(K_DEFAULT, tail=5.0, drop=0.1,
                                           rounds=10)
    return {
        "K": K_DEFAULT, "tail_p95_over_p50": 5.0, "drop_prob": 0.1,
        "fill_fraction": FILL_FRACTION,
        "sync_goodput_rows_per_s": sync_g,
        "stream_goodput_rows_per_s": stream_g,
        "goodput_ratio": ratio, "goodput_bar": 2.0,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--csv", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: equivalence + goodput asserts")
    ap.add_argument("--rounds", type=int, default=20,
                    help="simulated rounds per sweep cell")
    args = ap.parse_args()

    if args.smoke:
        raise SystemExit(smoke())

    check_accumulator_equivalence()
    print("accumulator == one-shot aggregate: bit-equal (oracle + kernel)")
    if args.csv:
        print("K,tail,drop,sync_rows_per_s,stream_rows_per_s,ratio")
    else:
        print(f"{'K':>4} {'tail':>5} {'drop':>5} {'sync_g':>9} "
              f"{'stream_g':>9} {'ratio':>7}")
    for tail in TAIL_SWEEP:
        for drop in DROP_SWEEP:
            sync_g, stream_g, ratio = goodput_pair(
                K_DEFAULT, tail, drop, rounds=args.rounds)
            if args.csv:
                print(f"{K_DEFAULT},{tail},{drop},{sync_g:.2f},"
                      f"{stream_g:.2f},{ratio:.2f}")
            else:
                print(f"{K_DEFAULT:>4} {tail:>5.1f} {drop:>5.2f} "
                      f"{sync_g:>9.2f} {stream_g:>9.2f} {ratio:>6.2f}x")


if __name__ == "__main__":
    main()
