"""Benchmark entrypoint — one benchmark per paper table/figure plus the
assignment's roofline table. Prints ``name,us_per_call,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run --quick    # skip FL training
  PYTHONPATH=src python -m benchmarks.run --json     # BENCH_<name>.json

``--json`` skips the CSV sweeps and instead writes one
``BENCH_<name>.json`` per registered bench (``repro.obs.export
.BENCH_REPORTS``: aggregation, retrieval, streaming, channel,
satisfaction, strategies, obs) into the working directory — smoke-scale
timings plus the acceptance-bar values each bench's ``--smoke`` mode
asserts, for machine consumption (dashboards, regression diffs). Each
bench only supplies a ``json_report()`` payload; the open/dump/print
plumbing lives once in ``repro.obs.export`` (DESIGN.md §14).
"""
import sys
from pathlib import Path

# self-locate: `python benchmarks/run.py` works like `python -m
# benchmarks.run` (repo root for the benchmarks package, src/ for repro)
_ROOT = Path(__file__).resolve().parent.parent
for _p in (str(_ROOT), str(_ROOT / "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

import argparse


def _write_json() -> None:
    from repro.obs import export

    export.write_all_bench_reports()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="skip the FL-training benchmark (Fig. 4)")
    ap.add_argument("--fig4-rounds", type=int, default=10)
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_<name>.json reports instead of CSV")
    args = ap.parse_args()

    if args.json:
        _write_json()
        return

    print("name,us_per_call,derived")
    from benchmarks import bench_kernels
    bench_kernels.main(csv=True)

    from benchmarks import bench_satisfaction
    bench_satisfaction.main(csv=True)

    from benchmarks import bench_ablation
    bench_ablation.main(csv=True)

    from benchmarks import bench_roofline
    bench_roofline.main(csv=True)

    if not args.quick:
        from benchmarks import bench_strategies
        bench_strategies.main(rounds=args.fig4_rounds, csv=True)


if __name__ == '__main__':
    main()
