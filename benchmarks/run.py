"""Benchmark entrypoint — one benchmark per paper table/figure plus the
assignment's roofline table. Prints ``name,us_per_call,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run --quick    # skip FL training
"""
import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="skip the FL-training benchmark (Fig. 4)")
    ap.add_argument("--fig4-rounds", type=int, default=10)
    args = ap.parse_args()

    print("name,us_per_call,derived")
    from benchmarks import bench_kernels
    bench_kernels.main(csv=True)

    from benchmarks import bench_satisfaction
    bench_satisfaction.main(csv=True)

    from benchmarks import bench_ablation
    bench_ablation.main(csv=True)

    from benchmarks import bench_roofline
    bench_roofline.main(csv=True)

    if not args.quick:
        from benchmarks import bench_strategies
        bench_strategies.main(rounds=args.fig4_rounds, csv=True)


if __name__ == '__main__':
    main()
