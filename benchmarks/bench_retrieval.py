"""Retrieval-engine scaling: arena-backed batched top-k vs the legacy
per-client scan (DESIGN.md §10).

The planning path the RAG planner used to run was 2 stores x 4 precision
levels x K clients = 8K serial numpy scans per round, each re-reading the
whole (N, D) record matrix. The cohort-batched engine issues ONE batched
query per store per round. This bench sweeps record count N x cohort
size K and reports:

- ``legacy_ms``:  8K serial scans (gemv + exact stable top-k per query —
  the legacy ``VectorStore.query`` inner loop on raw arrays, i.e. a
  *conservative* baseline: real legacy also paid python Record overhead
  and re-embedding per level),
- ``batched_ms``: 2 engine calls (one (K, D) GEMM + stable top-k each),
- their speedup, and the int8-vs-f32 arena memory ratio.

``--smoke`` is the CI mode (scripts/tier1.sh): asserts the batched
engine's top-k == brute-force numpy exactly on an f32 store (scores and
indices), the Pallas kernel == the jnp oracle bitwise on a ragged N, and
the int8 storage class stays under 0.3x of f32 bytes; exits non-zero on
violation. ``--full`` extends the sweep to N = 1M records. Runnable
standalone (self-locates ``src/``) or via scripts/tier1.sh.
"""
from __future__ import annotations

import argparse
import pathlib
import sys
import time

try:
    import repro  # noqa: F401  (importability probe)
except ImportError:  # standalone invocation: put <repo>/src on sys.path
    sys.path.insert(
        0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

import numpy as np

from repro.core.profiling.ragdb import RETRIEVE_K
from repro.retrieval import (ArenaStore, RetrievalEngine, brute_force_topk,
                             normalize_rows, stable_topk)

D = 256          # EMBED_DIM of the RAG feature hashing
N_LEVELS = 4     # precision levels the legacy evaluator queried per store
N_STORES = 2     # context-feedback + hardware-perf databases

QUICK_SWEEP = [
    (1_000, 64), (10_000, 64), (100_000, 8), (100_000, 64),
]
FULL_EXTRA = [
    (1_000_000, 64),
]


def _make_arenas(n: int, seed: int = 0):
    rng = np.random.RandomState(seed)
    vecs = normalize_rows(rng.randn(n, D).astype(np.float32))
    f32 = ArenaStore(D)
    f32.add_batch(vecs)
    int8 = ArenaStore(D, storage="int8")
    int8.add_batch(vecs)
    return vecs, f32, int8


def _queries(k_cohort: int, seed: int = 1):
    rng = np.random.RandomState(seed)
    return normalize_rows(rng.randn(k_cohort, D).astype(np.float32))


def _legacy_planning_pass(mat: np.ndarray, queries: np.ndarray, k: int):
    """The pre-PR-4 planner retrieval pattern: one numpy scan per client
    per store per precision level (the estimators re-queried per bits)."""
    out = None
    for q in queries:
        for _ in range(N_STORES * N_LEVELS):
            sims = mat @ q
            out = stable_topk(sims[None], k)
    return out


def _batched_planning_pass(engine: RetrievalEngine, queries: np.ndarray,
                           k: int):
    """The cohort path: one engine query per store per round."""
    out = None
    for _ in range(N_STORES):
        out = engine.topk(queries, k)
    return out


def bench_pair(n: int, k_cohort: int, reps: int = 3):
    """Returns (legacy_s, batched_s, speedup, int8_mem_ratio)."""
    vecs, f32, int8 = _make_arenas(n)
    queries = _queries(k_cohort)
    engine = RetrievalEngine(f32)
    _batched_planning_pass(engine, queries, RETRIEVE_K)  # warm
    t0 = time.perf_counter()
    for _ in range(reps):
        _batched_planning_pass(engine, queries, RETRIEVE_K)
    batched_s = (time.perf_counter() - t0) / reps
    _legacy_planning_pass(vecs[:256], queries[:1], RETRIEVE_K)  # warm
    t0 = time.perf_counter()
    _legacy_planning_pass(vecs, queries, RETRIEVE_K)
    legacy_s = time.perf_counter() - t0
    return (legacy_s, batched_s, legacy_s / batched_s,
            int8.nbytes / f32.nbytes)


def smoke() -> int:
    """CI mode: exact-equivalence + storage-class asserts (~seconds)."""
    import jax.numpy as jnp

    from repro.kernels.ops import topk_cosine

    n, k_cohort, k = 3000, 16, RETRIEVE_K  # n ragged vs the 256 tile
    _, f32, int8 = _make_arenas(n)
    queries = _queries(k_cohort)

    # batched engine == brute-force numpy, exactly (scores AND indices)
    s_eng, i_eng = RetrievalEngine(f32, use_kernel=False).topk(queries, k)
    s_bf, i_bf = brute_force_topk(f32.vectors(), queries, k)
    np.testing.assert_array_equal(i_eng, i_bf)
    np.testing.assert_array_equal(s_eng, s_bf)

    # Pallas kernel == jnp oracle, bitwise, on the ragged capacity slab
    data, _ = f32.raw()
    args = (jnp.asarray(queries), jnp.asarray(data), None, jnp.int32(n))
    s_k, i_k = topk_cosine(*args, k=k, use_kernel=True)
    s_o, i_o = topk_cosine(*args, k=k, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(s_k), np.asarray(s_o))
    np.testing.assert_array_equal(np.asarray(i_k), np.asarray(i_o))
    np.testing.assert_array_equal(np.asarray(i_k), i_eng)

    # int8 storage class: bounded memory, usable recall
    ratio = int8.nbytes / f32.nbytes
    assert ratio <= 0.3, f"int8 arena ratio {ratio} above 0.3"
    _, i8 = RetrievalEngine(int8, use_kernel=False).topk(queries, 10)
    _, i32 = RetrievalEngine(f32, use_kernel=False).topk(queries, 10)
    overlap = np.mean([len(set(a) & set(b)) / 10 for a, b in zip(i8, i32)])
    assert overlap >= 0.7, f"int8 recall@10 {overlap} below 0.7"

    legacy_s, batched_s, speedup, _ = bench_pair(20_000, k_cohort, reps=2)
    print(f"smoke OK: batched == brute force exactly (N={n}, K={k_cohort}, "
          f"k={k}), kernel == oracle bitwise, int8 ratio {ratio:.3f}, "
          f"recall@10 {overlap:.2f}; 20k-record planning pass "
          f"{legacy_s * 1e3:.1f}ms legacy vs {batched_s * 1e3:.1f}ms "
          f"batched ({speedup:.1f}x)")
    return 0


def json_report() -> dict:
    """Machine-readable smoke-scale numbers (benchmarks/run.py --json)."""
    n, k_cohort = 20_000, 16
    legacy_s, batched_s, speedup, ratio = bench_pair(n, k_cohort, reps=2)
    return {
        "N": n, "K": k_cohort,
        "legacy_ms": legacy_s * 1e3, "batched_ms": batched_s * 1e3,
        "speedup": speedup,
        "int8_mem_ratio": ratio, "int8_mem_bar": 0.3,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="include the 1M-record config")
    ap.add_argument("--csv", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: exact-equivalence asserts")
    args = ap.parse_args()

    if args.smoke:
        raise SystemExit(smoke())

    sweep = QUICK_SWEEP + (FULL_EXTRA if args.full else [])
    if args.csv:
        print("N,K,legacy_ms,batched_ms,speedup,int8_mem_ratio")
    else:
        print(f"{'N':>9} {'K':>4} {'legacy_ms':>10} {'batched_ms':>11} "
              f"{'speedup':>8} {'int8_mem':>9}")
    for n, k_cohort in sweep:
        legacy_s, batched_s, speedup, ratio = bench_pair(n, k_cohort)
        if args.csv:
            print(f"{n},{k_cohort},{legacy_s*1e3:.1f},{batched_s*1e3:.1f},"
                  f"{speedup:.1f},{ratio:.4f}")
        else:
            print(f"{n:>9} {k_cohort:>4} {legacy_s*1e3:>10.1f} "
                  f"{batched_s*1e3:>11.1f} {speedup:>7.1f}x {ratio:>9.3f}")


if __name__ == "__main__":
    main()
