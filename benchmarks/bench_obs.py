"""Telemetry-layer bench: tracer overhead + round-trace acceptance
(DESIGN.md §14).

The observability subsystem (``repro.obs``) must be free when off and
cheap when on. This bench measures both halves on the OTA data plane:

- **overhead**: per-call time of ``ota.ota_aggregate_packed`` on a
  K=32 mixed-precision packed cohort with the tracer enabled vs forced
  off (``obs.disabled()``), min-of-reps so scheduler noise doesn't
  decide the bar;
- **round trace**: one ``FLServer.run_round`` under ``obs.enabled()``
  over the fading channel, checked against the acceptance criteria —
  >= 7 distinct pipeline span names, a Perfetto ``trace_event`` export
  that ``json.loads`` round-trips with ``ph``/``ts``/``dur`` keys, and
  a metrics snapshot whose ``fl.uplink_bytes`` / ``fl.downlink_bytes``
  are bit-identical to the ``RoundLog`` that fed them, alongside
  ``ota.truncation_rate`` and the ``jax.retraces`` jit-cache counter.

``--smoke`` is the CI mode (scripts/tier1.sh): hard-asserts the bars
above plus tracer overhead < 5% and enabled-vs-disabled round-output
bit-identity, and writes the two CI artifacts —
``TELEMETRY_events.jsonl`` (the JSONL metric/span ledger) and
``TELEMETRY_round_trace.json`` (the Perfetto trace; load it at
``ui.perfetto.dev``).

Usage: python benchmarks/bench_obs.py [--smoke]
Runnable standalone (self-locates ``src/``) or via scripts/tier1.sh.
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys
import time

try:
    import repro  # noqa: F401  (importability probe)
except ImportError:  # standalone invocation: put <repo>/src on sys.path
    sys.path.insert(
        0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.base import FLConfig
from repro.core import ota, packing

# the required distinct pipeline span names a single round must emit
# (acceptance bar: >= 7; the instrumented loop emits these 9 on the
# ideal channel and adds channel_sample under the fading channel)
ROUND_SPANS = (
    "round", "plan", "client_train", "uplink_encode", "fold",
    "finalize", "optimizer", "broadcast_encode", "feedback",
)

EVENTS_PATH = "TELEMETRY_events.jsonl"
TRACE_PATH = "TELEMETRY_round_trace.json"


def _packed_cohort(K: int = 32, M: int = 1 << 14, seed: int = 0):
    """Mixed-precision packed wire rows for a K-client cohort."""
    rng = np.random.RandomState(seed)
    tree = {"w": jnp.asarray(rng.randn(M).astype(np.float32) * 0.01)}
    layout = packing.make_layout(tree)
    X = jnp.asarray(rng.randn(K, layout.padded_size).astype(np.float32)
                    * 0.01)
    bits = [(4, 8, 8, 16, 32)[i % 5] for i in range(K)]
    weights = [1.0 + (i % 3) for i in range(K)]
    key = jax.random.key(seed)
    sr = ota.derive_sr_seed(key)
    rows = [ota.quantize_uplink(X[i], b, sr, i) for i, b in enumerate(bits)]
    jax.block_until_ready([r.data for r in rows])
    return rows, bits, weights, layout, key


def _time_agg(rows, bits, weights, layout, key, reps: int) -> float:
    """Min-of-reps per-call seconds of the packed aggregation."""
    cfg = ota.OTAConfig(snr_db=20.0)
    out, _ = ota.ota_aggregate_packed(key, rows, bits, weights, layout, cfg)
    jax.block_until_ready(jax.tree.leaves(out))  # warm: compile + caches
    best = float("inf")
    for r in range(reps):
        t0 = time.perf_counter()
        out, _ = ota.ota_aggregate_packed(jax.random.key(r), rows, bits,
                                          weights, layout, cfg)
        jax.block_until_ready(jax.tree.leaves(out))
        best = min(best, time.perf_counter() - t0)
    return best


def bench_overhead(K: int = 32, M: int = 1 << 14, reps: int = 20):
    """(enabled_s, disabled_s, overhead_frac) for the K-cohort fold."""
    rows, bits, weights, layout, key = _packed_cohort(K, M)
    with obs.disabled():
        off_s = _time_agg(rows, bits, weights, layout, key, reps)
    with obs.enabled():
        on_s = _time_agg(rows, bits, weights, layout, key, reps)
    return on_s, off_s, on_s / off_s - 1.0


def trace_round(*, enabled: bool = True, seed: int = 0):
    """One fading-channel FL round; returns (server, log, span names,
    metrics snapshot). ``enabled=False`` runs it with telemetry forced
    off — the bit-identity baseline."""
    from repro.fl.server import FLServer

    cfg = FLConfig(n_clients=6, clients_per_round=4, n_rounds=1,
                   local_steps=1, local_batch=2, lr=1e-3,
                   planner="unified", channel_model="fading", seed=seed)
    ctx = obs.enabled() if enabled else obs.disabled()
    with ctx:
        obs.metrics.reset()
        n0 = len(obs.get_tracer().events)  # disabled() keeps old events
        srv = FLServer(cfg, shard_size=4)
        log = srv.run_round(0)
        names = {e.name for e in obs.get_tracer().events[n0:]}
        snap = obs.metrics.snapshot()
    return srv, log, names, snap


def smoke() -> int:
    """CI mode: hard-asserted acceptance bars (~a minute on CPU)."""
    # ---- one traced round: spans, Perfetto export, metrics snapshot
    srv, log, names, snap = trace_round(enabled=True)
    missing = [s for s in ROUND_SPANS if s not in names]
    assert not missing, f"round trace missing pipeline spans: {missing}"
    assert len(names) >= 7, f"expected >= 7 distinct spans, got {names}"

    doc = json.loads(obs.get_tracer().export_perfetto())
    evs = doc["traceEvents"]
    assert evs, "empty Perfetto export"
    for ev in evs:
        for k in ("name", "ph", "ts", "dur", "pid", "tid"):
            assert k in ev, f"trace event missing {k!r}: {ev}"
        assert ev["ph"] == "X", f"expected complete events, got {ev['ph']}"

    ctr, gau = snap["counters"], snap["gauges"]
    assert ctr["fl.uplink_bytes"] == log.uplink_bytes, \
        (ctr["fl.uplink_bytes"], log.uplink_bytes)
    assert ctr["fl.downlink_bytes"] == log.downlink_bytes, \
        (ctr["fl.downlink_bytes"], log.downlink_bytes)
    assert "ota.truncation_rate" in gau, sorted(gau)
    assert ctr.get("jax.retraces", 0) > 0, "jax retrace hook not firing"

    # ---- CI artifacts: JSONL ledger + Perfetto trace
    for p in (EVENTS_PATH, TRACE_PATH):
        if os.path.exists(p):
            os.remove(p)
    obs.export.dump_telemetry(EVENTS_PATH, TRACE_PATH)
    with open(EVENTS_PATH) as f:
        lines = [json.loads(ln) for ln in f]
    assert any(r["kind"] == "counter" and r["name"] == "fl.uplink_bytes"
               for r in lines), "JSONL ledger missing fl.uplink_bytes"
    assert any(r["kind"] == "span" and r["name"] == "round"
               for r in lines), "JSONL ledger missing round span rollup"
    print(f"wrote {EVENTS_PATH} ({len(lines)} events) and {TRACE_PATH} "
          f"({len(evs)} trace events)")

    # ---- disabled path: zero events, bit-identical round outputs
    srv_off, log_off, names_off, _ = trace_round(enabled=False)
    assert not names_off, f"disabled tracer recorded spans: {names_off}"
    assert log_off.uplink_bytes == log.uplink_bytes
    assert log_off.n_participating == log.n_participating
    for a, b in zip(jax.tree.leaves(srv.params),
                    jax.tree.leaves(srv_off.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # ---- tracer overhead on the K=32 data plane
    on_s, off_s, frac = bench_overhead()
    print(f"K=32 fold: {off_s*1e3:.2f}ms off, {on_s*1e3:.2f}ms on "
          f"({frac*100:+.1f}% overhead; bar < 5%)")
    assert frac < 0.05, f"tracer overhead {frac*100:.1f}% above 5%"

    print(f"smoke OK: {len(names)} distinct spans, Perfetto round-trip, "
          f"byte counters == RoundLog, disabled path bit-identical")
    return 0


def json_report() -> dict:
    """Machine-readable smoke-scale numbers (benchmarks/run.py --json)."""
    _, log, names, snap = trace_round(enabled=True)
    spans = obs.get_tracer().summary()  # before bench_overhead resets
    on_s, off_s, frac = bench_overhead(reps=10)
    return {
        "span_names": sorted(names),
        "n_span_names": len(names),
        "span_rollup": spans,
        "uplink_bytes": log.uplink_bytes,
        "downlink_bytes": log.downlink_bytes,
        "truncation_rate": snap["gauges"].get("ota.truncation_rate"),
        "jax_retraces": snap["counters"].get("jax.retraces"),
        "overhead_on_ms": on_s * 1e3,
        "overhead_off_ms": off_s * 1e3,
        "overhead_frac": frac,
        "overhead_bar": 0.05,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: trace/metrics acceptance + overhead bar")
    args = ap.parse_args()

    if args.smoke:
        raise SystemExit(smoke())

    _, log, names, snap = trace_round(enabled=True)
    print(f"round spans ({len(names)}): {sorted(names)}")
    print(f"uplink {log.uplink_bytes} B, downlink {log.downlink_bytes} B, "
          f"truncation {snap['gauges'].get('ota.truncation_rate'):.3f}, "
          f"retraces {snap['counters'].get('jax.retraces'):.0f}")
    for name, roll in sorted(obs.get_tracer().summary().items()):
        print(f"  {name:18s} n={roll['count']:<4d} "
              f"total={roll['total_us']/1e3:9.2f}ms "
              f"max={roll['max_us']/1e3:8.2f}ms")
    on_s, off_s, frac = bench_overhead()
    print(f"K=32 fold overhead: {off_s*1e3:.2f}ms off / {on_s*1e3:.2f}ms "
          f"on = {frac*100:+.1f}%")


if __name__ == "__main__":
    main()
